//! Configuration system: a TOML-subset parser (no external crates available
//! offline) + typed experiment configs with validation.
//!
//! Supported TOML subset — everything our configs and examples use:
//! `[table]` / `[table.sub]` headers, `key = value` with strings, integers,
//! floats, booleans, and homogeneous arrays; `#` comments. No inline tables,
//! no arrays-of-tables, no multi-line strings (parse errors name the line).

pub mod toml;

use crate::compress::plan::{ConvModelPlan, LayerPlan, SparsityPlan};
pub use toml::{TomlDoc, TomlValue};

/// Model choice for the CLI / examples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Lenet300,
    DeepMnist,
    Cifar10,
    TinyAlexnet,
    /// AlexNet-class conv model (strided conv1, grouped stages); trains at
    /// `alexnet_lite` scale, accounts at `ConvModelPlan::alexnet` scale.
    Alexnet,
    /// ResNet-style residual conv model with a global-avg-pool head.
    TinyResnet,
}

/// Classes of the synthetic ImageNet-like dataset the conv models train on.
const IMAGENET_LIKE_CLASSES: usize = 16;

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "lenet" | "lenet300" | "lenet-300-100" => Ok(Self::Lenet300),
            "deep_mnist" | "deepmnist" => Ok(Self::DeepMnist),
            "cifar10" | "cifar" => Ok(Self::Cifar10),
            "tiny_alexnet" | "tinyalexnet" => Ok(Self::TinyAlexnet),
            "alexnet" => Ok(Self::Alexnet),
            "tinyresnet" | "tiny_resnet" | "resnet" => Ok(Self::TinyResnet),
            other => Err(format!(
                "unknown model {other} (try lenet|deep_mnist|cifar10|tiny_alexnet|alexnet|tinyresnet)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Lenet300 => "lenet",
            Self::DeepMnist => "deep_mnist",
            Self::Cifar10 => "cifar10",
            Self::TinyAlexnet => "tiny_alexnet",
            Self::Alexnet => "alexnet",
            Self::TinyResnet => "tinyresnet",
        }
    }

    /// Train-step artifact name for this model.
    pub fn train_artifact(&self) -> &'static str {
        match self {
            Self::Lenet300 => "lenet_train_step_b50",
            Self::DeepMnist => "deep_mnist_train_step_b32",
            Self::Cifar10 => "cifar10_train_step_b32",
            Self::TinyAlexnet => "tiny_alexnet_train_step_b32",
            Self::Alexnet => "alexnet_train_step_b32",
            Self::TinyResnet => "tinyresnet_train_step_b32",
        }
    }

    /// Inference artifact name.
    pub fn infer_artifact(&self) -> &'static str {
        match self {
            Self::Lenet300 => "lenet_infer_b32",
            Self::DeepMnist => "deep_mnist_infer_b128",
            Self::Cifar10 => "cifar10_infer_b128",
            Self::TinyAlexnet => "tiny_alexnet_infer_b128",
            Self::Alexnet => "alexnet_infer_b128",
            Self::TinyResnet => "tinyresnet_infer_b128",
        }
    }

    /// The *training-scale* sparsity plan used on this testbed (lenet trains
    /// at paper scale; conv models use the scaled "lite" FC dims that match
    /// the artifacts — see DESIGN.md §2). For the conv model families this is
    /// the FC *head* of [`Self::conv_plan`].
    pub fn plan(&self, k: usize) -> Result<SparsityPlan, String> {
        match self {
            Self::Lenet300 => SparsityPlan::new(vec![
                LayerPlan::masked("fc1", 300, 784, k),
                LayerPlan::masked("fc2", 100, 300, k),
                LayerPlan::dense("fc3", 10, 100),
            ]),
            Self::DeepMnist => SparsityPlan::new(vec![
                LayerPlan::masked("fc1", 256, 784, k),
                LayerPlan::dense("fc2", 10, 256),
            ]),
            Self::Cifar10 => SparsityPlan::new(vec![
                LayerPlan::masked("fc1", 192, 2048, k),
                LayerPlan::masked("fc2", 96, 192, k),
                LayerPlan::dense("fc3", 10, 96),
            ]),
            Self::TinyAlexnet => SparsityPlan::new(vec![
                LayerPlan::masked("fc6", 256, 1024, k),
                LayerPlan::masked("fc7", 256, 256, k),
                LayerPlan::masked("fc8", 16, 256, k.min(16)),
            ]),
            // Mirror `ConvModelPlan::alexnet_lite(k, 16).fc` / `tinyresnet(k, 16).fc`,
            // but through the validating ctor so absurd `k` is a config error,
            // not a panic inside the static conv-plan builders.
            Self::Alexnet => SparsityPlan::new(vec![
                LayerPlan::masked("fc6", 128, 768, k),
                LayerPlan::masked("fc7", IMAGENET_LIKE_CLASSES, 128, k.min(IMAGENET_LIKE_CLASSES)),
            ]),
            Self::TinyResnet => SparsityPlan::new(vec![LayerPlan::masked(
                "fc1",
                IMAGENET_LIKE_CLASSES,
                32,
                k.min(8),
            )]),
        }
    }

    /// Paper-scale plan (used for Table-1 parameter accounting).
    pub fn paper_plan(&self, k: usize) -> SparsityPlan {
        match self {
            Self::Lenet300 => SparsityPlan::lenet300(k),
            Self::DeepMnist => SparsityPlan::deep_mnist(k),
            Self::Cifar10 => SparsityPlan::cifar10(k),
            Self::TinyAlexnet | Self::Alexnet => SparsityPlan::alexnet(k),
            // no paper FC analog: the residual model's only FC layer
            Self::TinyResnet => ConvModelPlan::tinyresnet(k, IMAGENET_LIKE_CLASSES).fc,
        }
    }

    /// The *training-scale* compressed-conv plan this model serves through
    /// the im2col lowering, when it has one (`None` = pure-FC model).
    pub fn conv_plan(&self, k: usize) -> Option<ConvModelPlan> {
        match self {
            Self::DeepMnist => Some(ConvModelPlan::deep_mnist_lite(k)),
            Self::Alexnet => Some(ConvModelPlan::alexnet_lite(k, IMAGENET_LIKE_CLASSES)),
            Self::TinyResnet => Some(ConvModelPlan::tinyresnet(k, IMAGENET_LIKE_CLASSES)),
            _ => None,
        }
    }

    /// Paper/report-scale conv plan (accounting only — never CI-trained).
    pub fn paper_conv_plan(&self, k: usize) -> Option<ConvModelPlan> {
        match self {
            Self::DeepMnist => Some(ConvModelPlan::deep_mnist(k)),
            Self::Alexnet => Some(ConvModelPlan::alexnet(k)),
            Self::TinyResnet => Some(ConvModelPlan::tinyresnet(k, IMAGENET_LIKE_CLASSES)),
            _ => None,
        }
    }

    /// Serving variant name of the compressed-conv engine (`-int8` twin is
    /// derived by suffix).
    pub fn conv_variant(&self) -> Option<&'static str> {
        match self {
            Self::DeepMnist => Some("deep-mnist-mpd"),
            Self::Alexnet => Some("alexnet-mpd"),
            Self::TinyResnet => Some("tinyresnet-mpd"),
            _ => None,
        }
    }
}

/// Execution-engine knobs: persistent-pool sizing and the register-tile
/// shape of the packed block-diagonal kernel (see DESIGN.md §Engine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker-pool lanes for the packed engine. `0` = share the process-global
    /// pool (sized by the machine / `MPDC_POOL_THREADS`); `1` = single-thread;
    /// `n > 1` = a dedicated pool of `n` lanes per engine instance.
    ///
    /// Note: the global pool runs one job at a time, so engines of *multiple
    /// concurrently-serving models* sharing it serialize their layer GEMMs.
    /// That trade is fine for the single-model case; multi-model deployments
    /// should give each serving worker its own pool (`pool_threads > 1`, or
    /// `PlanBackend::with_pool` with a shared per-worker handle).
    pub pool_threads: usize,
    /// Register-tile batch rows (1/2/4/8).
    pub tile_batch: usize,
    /// Register-tile output rows (1/2/4/8).
    pub tile_rows: usize,
    /// SIMD kernel dispatch: `true` (default) resolves the best detected ISA
    /// at engine build time (still subject to the `MPDC_FORCE_SCALAR` env
    /// override); `false` pins the scalar oracle kernels. i8 output is
    /// bit-identical either way; f32 differs by the pinned-reorder bound
    /// (see DESIGN.md §SIMD).
    pub simd: bool,
    /// Measured tile autotuning at engine build: sweep the micro-kernel tile
    /// instantiations per block GEMM and pin the fastest on each op, cached
    /// in `results/TUNE_10.json` keyed by geometry/dtype/ISA (DESIGN.md
    /// §Fusion). Only affects scalar-dispatched GEMMs — SIMD kernels ignore
    /// the tile — and never changes scalar output bits (accumulation order
    /// is tile-independent).
    pub autotune: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            pool_threads: 0,
            tile_batch: crate::linalg::TileShape::DEFAULT.batch,
            tile_rows: crate::linalg::TileShape::DEFAULT.rows,
            simd: true,
            autotune: false,
        }
    }
}

impl EngineConfig {
    pub fn tile(&self) -> crate::linalg::TileShape {
        crate::linalg::TileShape { batch: self.tile_batch, rows: self.tile_rows }
    }

    pub fn validate(&self) -> Result<(), String> {
        self.tile().validate()?;
        if self.pool_threads > 1024 {
            return Err(format!("pool_threads {} is absurd (max 1024)", self.pool_threads));
        }
        Ok(())
    }
}

/// Int8 quantization knobs — the `[quant]` TOML table. Controls whether the
/// serving CLI registers `{variant}-int8` backends and how the post-training
/// calibrator samples activations (see `quant::calibrate`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    /// Register quantized `-int8` serving variants / emit int8 artifacts.
    pub enabled: bool,
    /// Activation samples the calibrator runs through the f32 model.
    pub calib_samples: usize,
    /// Batch size of the calibration forward passes.
    pub calib_batch: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self { enabled: true, calib_samples: 256, calib_batch: 64 }
    }
}

impl QuantConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.calib_samples == 0 {
            return Err("quant.calib_samples must be ≥ 1".into());
        }
        if self.calib_batch == 0 {
            return Err("quant.calib_batch must be ≥ 1".into());
        }
        Ok(())
    }
}

/// Compressed-conv serving knobs — the `[conv]` TOML table. Controls whether
/// `mpdc serve` trains and registers the `deep-mnist-mpd` conv variant (and,
/// together with `[quant] enabled`, its `-int8` twin) next to the FC
/// variants. Disabled ⇒ the conv routes simply don't exist and return 404.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvConfig {
    /// Register the conv serving variants.
    pub enabled: bool,
    /// Quick-train steps for the native conv trainer at serve startup
    /// (conv training is scalar-loop bound, so this defaults lower than the
    /// FC variants' step count).
    pub steps: usize,
}

impl Default for ConvConfig {
    fn default() -> Self {
        Self { enabled: true, steps: 60 }
    }
}

impl ConvConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.steps == 0 {
            return Err("conv.steps must be ≥ 1".into());
        }
        Ok(())
    }
}

/// Observability knobs — the `[obs]` TOML table. Controls whether serving
/// executors are built with per-op profiling (the `GET /debug/profile`
/// payload), the per-thread span ring capacity, and the default log level
/// used when the `MPDC_LOG` environment variable is unset (the env always
/// wins; see `obs::logger`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsConfig {
    /// Build serving executors with [`crate::exec::Executor::with_profiling`].
    pub profiling: bool,
    /// Per-thread span ring capacity (spans retained per recording thread).
    pub ring_capacity: usize,
    /// Default log level when `MPDC_LOG` is unset: one of
    /// `off|error|warn|info|debug|trace`, or empty to keep the built-in
    /// default (`info`).
    pub log_level: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self { profiling: true, ring_capacity: 1024, log_level: String::new() }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.ring_capacity == 0 {
            return Err("obs.ring_capacity must be ≥ 1".into());
        }
        if self.ring_capacity > 1 << 20 {
            return Err(format!("obs.ring_capacity {} is absurd (max 1048576)", self.ring_capacity));
        }
        if !self.log_level.is_empty() && crate::obs::Level::parse(&self.log_level).is_none() {
            return Err(format!(
                "obs.log_level {:?} must be one of off|error|warn|info|debug|trace",
                self.log_level
            ));
        }
        Ok(())
    }

    /// Install this config into the process-wide observability state: size
    /// the span rings and seed the logger's default level. Call once at
    /// startup, before serving traffic.
    pub fn apply(&self) {
        if let Some(level) = crate::obs::Level::parse(&self.log_level) {
            crate::obs::logger::set_default_level(level);
        }
        crate::obs::span::init(self.ring_capacity);
    }
}

/// HTTP serving knobs — the `[server]` TOML table. Transport-level settings
/// map onto [`crate::server::HttpConfig`]; batching-policy settings map onto
/// [`crate::server::BatcherConfig`] (one batcher per registered variant).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServerConfig {
    pub host: String,
    /// TCP port; 0 picks an ephemeral port.
    pub port: u16,
    /// Front-end mode: `"event"` (nonblocking readiness loop, the default)
    /// or `"blocking"` (thread-per-connection accept pool, the benchmark
    /// baseline).
    pub mode: String,
    /// Blocking mode only: fixed accept/worker thread count — the hard bound
    /// on concurrently served connections (excess connections wait in the
    /// kernel backlog).
    pub accept_threads: usize,
    /// Event mode only: number of event-loop threads (connections are
    /// sharded across them at accept time).
    pub event_threads: usize,
    /// Connections beyond this are shed with 503 + `Retry-After` before any
    /// bytes are read.
    pub max_connections: usize,
    /// Requests beyond this many concurrently dispatched inferences are shed
    /// with 429 + `Retry-After` before the body is read (0 = unlimited).
    pub max_inflight: usize,
    /// Per-client-IP in-flight cap so one hot client cannot monopolise the
    /// admission budget (0 = disabled).
    pub per_client_inflight: usize,
    pub keep_alive: bool,
    /// Deadline for reading a request (head + body), in ms; expiry → 408.
    pub read_timeout_ms: u64,
    /// Deadline for writing a queued response, in ms; expiry closes the
    /// connection.
    pub write_timeout_ms: u64,
    /// Idle keep-alive reaper: connections with no request in progress are
    /// closed after this long, in ms.
    pub idle_timeout_ms: u64,
    /// `Retry-After` header value attached to 429/503 shed responses, in
    /// seconds (0 omits the header).
    pub retry_after_s: u32,
    /// Request bodies above this return 413, in KiB.
    pub max_body_kb: usize,
    /// Dynamic batching: largest batch assembled per worker dispatch.
    pub max_batch: usize,
    /// Dynamic batching: wait after the first queued request, in µs
    /// (legacy fixed-window policy; used when `deadline_us` = 0).
    pub max_wait_us: u64,
    /// Dynamic batching: per-request latency budget in µs — the batcher
    /// waits `deadline − est(exec)` after the first queued request, capped
    /// by `max_wait_us`. 0 disables the budget and falls back to the fixed
    /// `max_wait_us` window.
    pub deadline_us: u64,
    /// Bounded admission queue per variant (backpressure → 429).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".into(),
            port: 8077,
            mode: "event".into(),
            accept_threads: 8,
            event_threads: 2,
            max_connections: 1024,
            max_inflight: 256,
            per_client_inflight: 0,
            keep_alive: true,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            idle_timeout_ms: 10_000,
            retry_after_s: 1,
            max_body_kb: 1024,
            max_batch: 32,
            max_wait_us: 2_000,
            deadline_us: 2_000,
            queue_depth: 256,
        }
    }
}

impl ServerConfig {
    pub fn addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }

    pub fn http_config(&self) -> crate::server::HttpConfig {
        crate::server::HttpConfig {
            addr: self.addr(),
            mode: crate::server::ServeMode::parse(&self.mode).unwrap_or_default(),
            accept_threads: self.accept_threads,
            event_threads: self.event_threads,
            max_connections: self.max_connections,
            max_inflight: self.max_inflight,
            per_client_inflight: self.per_client_inflight,
            keep_alive: self.keep_alive,
            read_timeout: std::time::Duration::from_millis(self.read_timeout_ms),
            write_timeout: std::time::Duration::from_millis(self.write_timeout_ms),
            idle_timeout: std::time::Duration::from_millis(self.idle_timeout_ms),
            max_body_bytes: self.max_body_kb * 1024,
            retry_after_s: self.retry_after_s,
        }
    }

    pub fn batcher_config(&self) -> crate::server::BatcherConfig {
        crate::server::BatcherConfig {
            max_batch: self.max_batch,
            max_wait: std::time::Duration::from_micros(self.max_wait_us),
            deadline: std::time::Duration::from_micros(self.deadline_us),
            queue_depth: self.queue_depth,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.host.is_empty() {
            return Err("server.host must not be empty".into());
        }
        if crate::server::ServeMode::parse(&self.mode).is_none() {
            return Err(format!("server.mode {:?} must be \"event\" or \"blocking\"", self.mode));
        }
        if self.accept_threads == 0 || self.accept_threads > 1024 {
            return Err(format!("server.accept_threads {} out of range 1..=1024", self.accept_threads));
        }
        if self.event_threads == 0 || self.event_threads > 1024 {
            return Err(format!("server.event_threads {} out of range 1..=1024", self.event_threads));
        }
        if self.max_connections == 0 {
            return Err("server.max_connections must be ≥ 1".into());
        }
        if self.max_batch == 0 {
            return Err("server.max_batch must be ≥ 1".into());
        }
        if self.queue_depth == 0 {
            return Err("server.queue_depth must be ≥ 1".into());
        }
        if self.max_body_kb == 0 {
            return Err("server.max_body_kb must be ≥ 1".into());
        }
        Ok(())
    }
}

/// A full experiment config (CLI defaults + TOML override).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelKind,
    pub nblocks: usize,
    pub seed: u64,
    pub steps: usize,
    pub lr: f32,
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    pub artifacts_dir: Option<String>,
    pub out_dir: String,
    pub engine: EngineConfig,
    pub server: ServerConfig,
    pub quant: QuantConfig,
    pub conv: ConvConfig,
    pub obs: ObsConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::Lenet300,
            nblocks: 10,
            seed: 42,
            steps: 400,
            lr: 0.05,
            lr_decay: 1.0,
            lr_decay_every: usize::MAX,
            train_samples: 2000,
            test_samples: 500,
            artifacts_dir: None,
            out_dir: "results".into(),
            engine: EngineConfig::default(),
            server: ServerConfig::default(),
            quant: QuantConfig::default(),
            conv: ConvConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file; missing keys keep defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Self::default();
        if let Some(v) = doc.get_str("experiment.model") {
            cfg.model = ModelKind::parse(v)?;
        }
        if let Some(v) = doc.get_int("experiment.nblocks") {
            cfg.nblocks = v as usize;
        }
        if let Some(v) = doc.get_int("experiment.seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_int("train.steps") {
            cfg.steps = v as usize;
        }
        if let Some(v) = doc.get_float("train.lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = doc.get_float("train.lr_decay") {
            cfg.lr_decay = v as f32;
        }
        if let Some(v) = doc.get_int("train.lr_decay_every") {
            cfg.lr_decay_every = v as usize;
        }
        if let Some(v) = doc.get_int("data.train_samples") {
            cfg.train_samples = v as usize;
        }
        if let Some(v) = doc.get_int("data.test_samples") {
            cfg.test_samples = v as usize;
        }
        if let Some(v) = doc.get_int("engine.pool_threads") {
            cfg.engine.pool_threads = v as usize;
        }
        if let Some(v) = doc.get_int("engine.tile_batch") {
            cfg.engine.tile_batch = v as usize;
        }
        if let Some(v) = doc.get_int("engine.tile_rows") {
            cfg.engine.tile_rows = v as usize;
        }
        if let Some(v) = doc.get_bool("engine.simd") {
            cfg.engine.simd = v;
        }
        if let Some(v) = doc.get_bool("engine.autotune") {
            cfg.engine.autotune = v;
        }
        if let Some(v) = doc.get_str("server.host") {
            cfg.server.host = v.to_string();
        }
        if let Some(v) = doc.get_int("server.port") {
            cfg.server.port =
                u16::try_from(v).map_err(|_| format!("server.port {v} out of range 0..=65535"))?;
        }
        if let Some(v) = doc.get_str("server.mode") {
            cfg.server.mode = v.to_string();
        }
        if let Some(v) = doc.get_int("server.accept_threads") {
            cfg.server.accept_threads = v as usize;
        }
        if let Some(v) = doc.get_int("server.event_threads") {
            cfg.server.event_threads = v as usize;
        }
        if let Some(v) = doc.get_int("server.max_connections") {
            cfg.server.max_connections = v as usize;
        }
        if let Some(v) = doc.get_int("server.max_inflight") {
            cfg.server.max_inflight = v as usize;
        }
        if let Some(v) = doc.get_int("server.per_client_inflight") {
            cfg.server.per_client_inflight = v as usize;
        }
        if let Some(v) = doc.get_bool("server.keep_alive") {
            cfg.server.keep_alive = v;
        }
        if let Some(v) = doc.get_int("server.read_timeout_ms") {
            cfg.server.read_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("server.write_timeout_ms") {
            cfg.server.write_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("server.idle_timeout_ms") {
            cfg.server.idle_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("server.retry_after_s") {
            cfg.server.retry_after_s = u32::try_from(v)
                .map_err(|_| format!("server.retry_after_s {v} out of range"))?;
        }
        if let Some(v) = doc.get_int("server.max_body_kb") {
            cfg.server.max_body_kb = v as usize;
        }
        if let Some(v) = doc.get_int("server.max_batch") {
            cfg.server.max_batch = v as usize;
        }
        if let Some(v) = doc.get_int("server.max_wait_us") {
            cfg.server.max_wait_us = v as u64;
        }
        if let Some(v) = doc.get_int("server.deadline_us") {
            cfg.server.deadline_us = v as u64;
        }
        if let Some(v) = doc.get_int("server.queue_depth") {
            cfg.server.queue_depth = v as usize;
        }
        if let Some(v) = doc.get_bool("quant.enabled") {
            cfg.quant.enabled = v;
        }
        if let Some(v) = doc.get_int("quant.calib_samples") {
            cfg.quant.calib_samples = v as usize;
        }
        if let Some(v) = doc.get_int("quant.calib_batch") {
            cfg.quant.calib_batch = v as usize;
        }
        if let Some(v) = doc.get_bool("conv.enabled") {
            cfg.conv.enabled = v;
        }
        if let Some(v) = doc.get_int("conv.steps") {
            cfg.conv.steps =
                usize::try_from(v).map_err(|_| format!("conv.steps {v} must be non-negative"))?;
        }
        if let Some(v) = doc.get_bool("obs.profiling") {
            cfg.obs.profiling = v;
        }
        if let Some(v) = doc.get_int("obs.ring_capacity") {
            cfg.obs.ring_capacity = usize::try_from(v)
                .map_err(|_| format!("obs.ring_capacity {v} must be non-negative"))?;
        }
        if let Some(v) = doc.get_str("obs.log_level") {
            cfg.obs.log_level = v.to_string();
        }
        if let Some(v) = doc.get_str("paths.artifacts") {
            cfg.artifacts_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("paths.out") {
            cfg.out_dir = v.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.nblocks == 0 {
            return Err("nblocks must be ≥ 1".into());
        }
        if self.steps == 0 {
            return Err("steps must be ≥ 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be positive".into());
        }
        if self.train_samples == 0 || self.test_samples == 0 {
            return Err("sample counts must be positive".into());
        }
        self.engine.validate()?;
        self.server.validate()?;
        self.quant.validate()?;
        self.conv.validate()?;
        self.obs.validate()?;
        // plan validity at this model/nblocks combination
        self.model.plan(self.nblocks)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("lenet").unwrap(), ModelKind::Lenet300);
        assert_eq!(ModelKind::parse("tiny_alexnet").unwrap(), ModelKind::TinyAlexnet);
        assert_eq!(ModelKind::parse("alexnet").unwrap(), ModelKind::Alexnet);
        assert_eq!(ModelKind::parse("tinyresnet").unwrap(), ModelKind::TinyResnet);
        assert_eq!(ModelKind::parse("resnet").unwrap(), ModelKind::TinyResnet);
        assert!(ModelKind::parse("vgg").is_err());
    }

    #[test]
    fn from_toml_overrides_defaults() {
        let text = r#"
# experiment file
[experiment]
model = "cifar10"
nblocks = 8
seed = 7

[train]
steps = 123
lr = 0.01

[data]
train_samples = 99
test_samples = 50

[paths]
out = "results/custom"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, ModelKind::Cifar10);
        assert_eq!(cfg.nblocks, 8);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.steps, 123);
        assert!((cfg.lr - 0.01).abs() < 1e-9);
        assert_eq!(cfg.train_samples, 99);
        assert_eq!(cfg.out_dir, "results/custom");
        // unspecified keys keep defaults
        assert_eq!(cfg.test_samples, 50);
        assert!((cfg.lr_decay - 1.0).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_bad_combos() {
        let mut cfg = ExperimentConfig::default();
        cfg.nblocks = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.model = ModelKind::TinyAlexnet;
        cfg.nblocks = 100_000; // exceeds layer dims
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn engine_config_parses_and_validates() {
        let text = r#"
[engine]
pool_threads = 4
tile_batch = 2
tile_rows = 8
simd = false
autotune = true
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.engine,
            EngineConfig {
                pool_threads: 4,
                tile_batch: 2,
                tile_rows: 8,
                simd: false,
                autotune: true,
            }
        );
        assert_eq!(cfg.engine.tile(), crate::linalg::TileShape { batch: 2, rows: 8 });
        // defaults when the table is absent (simd defaults on)
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.engine, EngineConfig::default());
        assert!(cfg.engine.simd);
        // bad tile shapes are rejected
        assert!(ExperimentConfig::from_toml("[engine]\ntile_batch = 3\n").is_err());
        let mut bad = ExperimentConfig::default();
        bad.engine.tile_rows = 7;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn server_config_parses_and_validates() {
        let text = r#"
[server]
host = "0.0.0.0"
port = 9000
mode = "blocking"
accept_threads = 16
event_threads = 4
max_inflight = 32
per_client_inflight = 4
max_batch = 64
max_wait_us = 500
deadline_us = 1500
queue_depth = 512
keep_alive = false
write_timeout_ms = 750
idle_timeout_ms = 2500
retry_after_s = 3
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.server.addr(), "0.0.0.0:9000");
        assert_eq!(cfg.server.mode, "blocking");
        assert_eq!(cfg.server.accept_threads, 16);
        assert_eq!(cfg.server.event_threads, 4);
        assert_eq!(cfg.server.max_batch, 64);
        assert!(!cfg.server.keep_alive);
        // unspecified keys keep defaults
        assert_eq!(cfg.server.queue_depth, 512);
        assert_eq!(cfg.server.max_connections, ServerConfig::default().max_connections);
        // conversions carry the policy through
        let bc = cfg.server.batcher_config();
        assert_eq!(bc.max_batch, 64);
        assert_eq!(bc.max_wait, std::time::Duration::from_micros(500));
        assert_eq!(bc.deadline, std::time::Duration::from_micros(1500));
        let hc = cfg.server.http_config();
        assert_eq!(hc.mode, crate::server::ServeMode::Blocking);
        assert_eq!(hc.accept_threads, 16);
        assert_eq!(hc.event_threads, 4);
        assert_eq!(hc.max_inflight, 32);
        assert_eq!(hc.per_client_inflight, 4);
        assert_eq!(hc.write_timeout, std::time::Duration::from_millis(750));
        assert_eq!(hc.idle_timeout, std::time::Duration::from_millis(2500));
        assert_eq!(hc.retry_after_s, 3);
        assert!(!hc.keep_alive);
        // the default mode is the event loop
        assert_eq!(
            ExperimentConfig::from_toml("").unwrap().server.http_config().mode,
            crate::server::ServeMode::Event
        );
        // invalid combinations rejected
        assert!(ExperimentConfig::from_toml("[server]\naccept_threads = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[server]\nevent_threads = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[server]\nmode = \"threaded\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[server]\nqueue_depth = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[server]\nport = 70000\n").is_err());
        assert!(ExperimentConfig::from_toml("[server]\nport = -1\n").is_err());
        let mut bad = ExperimentConfig::default();
        bad.server.max_batch = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn quant_config_parses_and_validates() {
        let text = r#"
[quant]
enabled = false
calib_samples = 512
calib_batch = 32
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.quant, QuantConfig { enabled: false, calib_samples: 512, calib_batch: 32 });
        // defaults when the table is absent: quantized variants on
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.quant, QuantConfig::default());
        assert!(cfg.quant.enabled);
        // invalid values rejected
        assert!(ExperimentConfig::from_toml("[quant]\ncalib_samples = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[quant]\ncalib_batch = 0\n").is_err());
    }

    #[test]
    fn conv_config_parses_and_validates() {
        let text = r#"
[conv]
enabled = false
steps = 25
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.conv, ConvConfig { enabled: false, steps: 25 });
        // defaults when the table is absent: conv variants on
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.conv, ConvConfig::default());
        assert!(cfg.conv.enabled);
        assert!(ExperimentConfig::from_toml("[conv]\nsteps = 0\n").is_err());
        // a negative step count must not wrap through the usize cast
        assert!(ExperimentConfig::from_toml("[conv]\nsteps = -1\n").is_err());
    }

    #[test]
    fn obs_config_parses_and_validates() {
        let text = r#"
[obs]
profiling = false
ring_capacity = 256
log_level = "debug"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.obs,
            ObsConfig { profiling: false, ring_capacity: 256, log_level: "debug".into() }
        );
        // defaults when the table is absent: profiling on, 1024 spans/thread
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.obs, ObsConfig::default());
        assert!(cfg.obs.profiling);
        assert_eq!(cfg.obs.ring_capacity, 1024);
        // invalid values rejected
        assert!(ExperimentConfig::from_toml("[obs]\nring_capacity = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[obs]\nring_capacity = -1\n").is_err());
        assert!(ExperimentConfig::from_toml("[obs]\nring_capacity = 2097152\n").is_err());
        assert!(ExperimentConfig::from_toml("[obs]\nlog_level = \"loud\"\n").is_err());
    }

    #[test]
    fn artifact_names_exist_for_all_models() {
        for m in [
            ModelKind::Lenet300,
            ModelKind::DeepMnist,
            ModelKind::Cifar10,
            ModelKind::TinyAlexnet,
            ModelKind::Alexnet,
            ModelKind::TinyResnet,
        ] {
            assert!(m.train_artifact().contains("train_step"));
            assert!(m.infer_artifact().contains("infer"));
            let plan = m.plan(8).unwrap();
            assert!(!plan.layers.is_empty());
        }
    }

    #[test]
    fn conv_model_fc_heads_match_training_plans() {
        // `plan()` hand-writes the conv models' FC heads so validation stays
        // fallible; they must stay dimension-identical to `conv_plan().fc`.
        for m in [ModelKind::Alexnet, ModelKind::TinyResnet] {
            let fc = m.plan(8).unwrap();
            let conv = m.conv_plan(8).unwrap();
            assert_eq!(fc.layers.len(), conv.fc.layers.len(), "{}", m.name());
            for (a, b) in fc.layers.iter().zip(&conv.fc.layers) {
                assert_eq!((a.out_dim, a.in_dim, a.nblocks), (b.out_dim, b.in_dim, b.nblocks));
            }
            assert!(m.conv_variant().is_some());
            assert!(m.paper_conv_plan(8).is_some());
        }
        // absurd nblocks is a config error, not a panic
        assert!(ModelKind::Alexnet.plan(100_000).is_err());
        assert!(ModelKind::Lenet300.conv_plan(8).is_none());
    }
}
