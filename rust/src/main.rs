//! `mpdc` — the MPDCompress command-line launcher.
//!
//! Subcommands (run `mpdc help` for details):
//!   masks       generate a mask, print stats, write PGM figures
//!   decompose   run the Fig.-1 sub-graph-separation demo
//!   report      compression accounting (Table-1 param columns) for a model
//!   train       train a model with MPD masks via the AOT/PJRT runtime
//!   quantize    post-training int8 quantization → checkpoint-v2 artifact
//!   plan        dump a model's compiled execution plan (op list, buffer
//!               sizes, MAC/storage accounting; f32/int8/mixed precision)
//!   profile     per-op execution profile of a compiled plan (calls, ns,
//!               GFLOP/s, GB/s) → stdout table + results/PROF_8.json
//!   serve       start the HTTP inference server (dense + MPD + -int8 +
//!               compressed-conv deep-mnist-mpd variants)
//!   loadgen     drive closed/open-loop load against a running server
//!   bench-fig1 / bench-fig4a / bench-fig4b / bench-fig5 / bench-table1 /
//!   bench-speedup   regenerate the paper's figures/tables
//!
//! Flags are `--key value`; `--config file.toml` loads an
//! [`mpdc::config::ExperimentConfig`] with CLI flags taking precedence.

use mpdc::config::{ExperimentConfig, ModelKind};
use mpdc::experiments::{common, figures, speedup, table1};
use mpdc::train::aot_trainer::TrainConfig;
use mpdc::util::benchkit::Table;
use mpdc::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, flags) = match parse_args(&args) {
        Ok(v) => v,
        Err(e) => {
            mpdc::log_error!("mpdc", "{e}");
            usage();
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "masks" => cmd_masks(&flags),
        "decompose" => cmd_decompose(&flags),
        "report" => cmd_report(&flags),
        "train" => cmd_train(&flags),
        "quantize" => cmd_quantize(&flags),
        "plan" => cmd_plan(&flags),
        "profile" => cmd_profile(&flags),
        "serve" => cmd_serve(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "bench-fig1" => cmd_fig1(&flags),
        "bench-fig4a" => cmd_fig4a(&flags),
        "bench-fig4b" => cmd_fig4b(&flags),
        "bench-fig5" => cmd_fig5(&flags),
        "bench-table1" => cmd_table1(&flags),
        "bench-speedup" => cmd_speedup(&flags),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            mpdc::log_error!("mpdc", "unknown command {other:?}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        mpdc::log_error!("mpdc", "{e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "mpdc — MPDCompress (matrix permutation decomposition DNN compression)

USAGE: mpdc <command> [--key value]...

COMMANDS
  masks          --rows N --cols N --blocks K [--seed S] [--out DIR]
  decompose      (Fig. 1 demo; no flags)
  report         --model M --nblocks K          Table-1 parameter accounting
  train          --model M --nblocks K [--steps N] [--lr F] [--seed S]
                 [--train-samples N] [--test-samples N] [--config FILE]
  quantize       [--ckpt FILE] [--model M] [--nblocks K] [--steps N]
                 [--seed S] [--out DIR] [--config FILE]
                 post-training int8 quantization: load (or quick-train) a
                 masked model, emit <model>_k<K>.packed.mpdc (f32) and
                 <model>_k<K>.int8.mpdc (checkpoint v2, i8 + scale
                 sidecars), report compression ratio + accuracy delta
                 ([quant] in TOML tunes calibration)
  plan           [--model M] [--nblocks K] [--seed S] [--batch N]
                 [--precision f32|int8|mixed] [--autotune] [--config FILE]
                 dump the compiled execution plan: one row per op with
                 per-sample shapes, activation-buffer bytes at --batch,
                 MACs and storage; conv-family models (deep_mnist,
                 alexnet, tinyresnet) additionally dump the
                 compressed-conv plan + paper-scale compression
                 accounting. --precision mixed quantizes masked
                 layers/stages to int8 and keeps dense ones f32
                 (per-layer mixed precision on one plan)
  profile        [--model M] [--nblocks K] [--seed S] [--batch N]
                 [--iters K] [--precision f32|int8|mixed] [--autotune]
                 [--config FILE]
                 run the compiled plan under the per-op profiler: warm,
                 time --iters batched runs, print per-op calls / total /
                 mean / min / max ns, time share, GFLOP/s and GB/s, check
                 per-op totals attribute ≥ 90% of wall time, and merge
                 the section into results/PROF_8.json; conv-family
                 models also profile their compressed-conv plan
  serve          [--port P] [--serve-mode event|blocking] [--steps N]
                 [--split dense:0.2,mpd:0.8] [--config FILE]
                 quick-train a masked LeNet, register dense + csr + mpd
                 (+ mpd-int8/dense-int8 unless quant.enabled=false;
                 + deep-mnist-mpd[-int8] conv variants unless
                 conv.enabled=false; --model alexnet|tinyresnet also
                 registers alexnet-mpd[-int8]|tinyresnet-mpd[-int8]),
                 serve HTTP ([server] in TOML)
  loadgen        [--host H] [--port P] [--variant V]
                 [--mode closed|open|sweep] [--qps F] [--concurrency N]
                 [--requests N] [--seed S] [--qps-points F,F,…]
                 [--concurrencies N,N,…]   drive load against a running
                 server; prints p50/p99 + req/s + per-status-class
                 latency; sweep mode walks an offered-load grid
  bench-fig1     [--out DIR]
  bench-fig4a    [--masks N] [--steps N] [--config FILE]
  bench-fig4b    [--masks N] [--out DIR]
  bench-fig5     [--steps N] [--config FILE]
  bench-table1   [--steps N] [--config FILE]
  bench-speedup  [--batch N] [--full]

MODELS: lenet | deep_mnist | cifar10 | tiny_alexnet | alexnet | tinyresnet"
    );
}

type Flags = HashMap<String, String>;

fn parse_args(args: &[String]) -> Result<(String, Flags), String> {
    let cmd = args.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got {:?}", args[i]))?
            .to_string();
        // boolean flags
        if i + 1 >= args.len() || args[i + 1].starts_with("--") {
            flags.insert(key, "true".into());
            i += 1;
        } else {
            flags.insert(key, args[i + 1].clone());
            i += 2;
        }
    }
    Ok((cmd, flags))
}

fn cfg_from_flags(flags: &Flags) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path)?;
        ExperimentConfig::from_toml(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(m) = flags.get("model") {
        cfg.model = ModelKind::parse(m).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(v) = flags.get("nblocks") {
        cfg.nblocks = v.parse()?;
    }
    if let Some(v) = flags.get("steps") {
        cfg.steps = v.parse()?;
    }
    if let Some(v) = flags.get("lr") {
        cfg.lr = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flags.get("train-samples") {
        cfg.train_samples = v.parse()?;
    }
    if let Some(v) = flags.get("test-samples") {
        cfg.test_samples = v.parse()?;
    }
    if let Some(v) = flags.get("autotune") {
        cfg.engine.autotune = v.parse()?;
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    if let Some(dir) = &cfg.artifacts_dir {
        std::env::set_var("MPDC_ARTIFACTS", dir);
    }
    Ok(cfg)
}

fn train_cfg(cfg: &ExperimentConfig) -> TrainConfig {
    TrainConfig {
        steps: cfg.steps,
        lr: cfg.lr,
        lr_decay: cfg.lr_decay,
        lr_decay_every: cfg.lr_decay_every,
        log_every: (cfg.steps / 20).max(1),
        seed: cfg.seed,
    }
}

fn out_dir(flags: &Flags) -> PathBuf {
    PathBuf::from(flags.get("out").cloned().unwrap_or_else(|| "results".into()))
}

/// Apply `--autotune`: measure + pin per-op micro-kernel tiles against the
/// persisted cache (results/TUNE_10.json). No-op unless the flag/config set
/// `engine.autotune`.
fn maybe_autotune(exec: mpdc::exec::Executor, cfg: &ExperimentConfig) -> mpdc::exec::Executor {
    if !cfg.engine.autotune {
        return exec;
    }
    use mpdc::compress::tilespace::TileTuner;
    let path = TileTuner::default_path();
    let mut tuner = TileTuner::load(&path);
    let exec = exec.autotune_tiles(&mut tuner);
    match tuner.save(&path) {
        Ok(()) => println!("autotune: {} tile entries cached in {}", tuner.len(), path.display()),
        Err(e) => mpdc::log_error!("mpdc", "tile cache {} not persisted: {e}", path.display()),
    }
    exec
}

// ---------------------------------------------------------------- commands

fn cmd_masks(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::mask::mask::MpdMask;
    use mpdc::mask::prng::Xoshiro256pp;
    let rows: usize = flags.get("rows").map(|s| s.parse()).transpose()?.unwrap_or(300);
    let cols: usize = flags.get("cols").map(|s| s.parse()).transpose()?.unwrap_or(100);
    let blocks: usize = flags.get("blocks").map(|s| s.parse()).transpose()?.unwrap_or(10);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mask = MpdMask::generate(rows, cols, blocks, &mut rng);
    println!(
        "mask {rows}×{cols} blocks={blocks}: nnz={} density={:.3}% compression={:.2}×",
        mask.nnz(),
        mask.density() * 100.0,
        mask.layout.compression()
    );
    let dir = out_dir(flags);
    mpdc::util::pgm::write_pgm(&dir.join("mask_b.pgm"), &mask.layout.to_dense(), rows, cols)?;
    mpdc::util::pgm::write_pgm(&dir.join("mask_m.pgm"), &mask.to_dense(), rows, cols)?;
    println!("wrote {}/mask_b.pgm and mask_m.pgm", dir.display());
    Ok(())
}

fn cmd_decompose(_flags: &Flags) -> anyhow::Result<()> {
    use mpdc::mask::decompose::{apply_decomposition, decompose, fig1_example, verify_decomposition};
    let (m, rows, cols) = fig1_example();
    println!("Fig. 1(a) input (4×4 irregular sparse):");
    for r in 0..rows {
        println!("  {:?}", &m[r * cols..(r + 1) * cols]);
    }
    let d = decompose(&m, rows, cols);
    println!(
        "\nsub-graph separation found: {} components; row perm {:?}, col perm {:?}",
        d.ncomponents,
        d.p_row.as_slice(),
        d.p_col.as_slice()
    );
    let blocked = apply_decomposition(&m, rows, cols, &d);
    println!("\nFig. 1(c) block-diagonalized:");
    for r in 0..rows {
        println!("  {:?}", &blocked[r * cols..(r + 1) * cols]);
    }
    println!("\nverified: {}", verify_decomposition(&m, rows, cols, &d));
    Ok(())
}

fn cmd_report(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::compress::compressor::MpdCompressor;
    let cfg = cfg_from_flags(flags)?;
    let comp = MpdCompressor::new(cfg.model.paper_plan(cfg.nblocks), cfg.seed);
    let r = comp.report();
    let mut t = Table::new(&["layer", "dense params", "kept", "compression", "dense B", "CSR B", "packed B"]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.dense_params.to_string(),
            l.kept_params.to_string(),
            format!("{:.2}×", l.compression),
            l.dense_bytes.to_string(),
            l.csr_bytes.to_string(),
            l.packed_bytes.to_string(),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        r.total_dense_params().to_string(),
        r.total_kept_params().to_string(),
        format!("{:.2}×", r.overall_compression()),
        r.total_dense_bytes().to_string(),
        r.total_csr_bytes().to_string(),
        r.total_packed_bytes().to_string(),
    ]);
    println!("{} (paper scale, {} blocks)\n{}", cfg.model.name(), cfg.nblocks, t.render());
    Ok(())
}

fn cmd_train(flags: &Flags) -> anyhow::Result<()> {
    let cfg = cfg_from_flags(flags)?;
    let engine = common::try_engine().ok_or_else(|| anyhow::anyhow!("artifacts missing"))?;
    let (train, test) = common::make_datasets(cfg.model, cfg.train_samples, cfg.test_samples, cfg.seed);
    let (_, masks) = common::dense_mask_inputs(cfg.model, cfg.nblocks, cfg.seed, false);
    let dir = out_dir(flags);
    std::fs::create_dir_all(&dir)?;
    let log = dir.join(format!("{}_loss.jsonl", cfg.model.name()));
    mpdc::log_info!(
        "train",
        "training {} with {} blocks for {} steps (lr {})…",
        cfg.model.name(),
        cfg.nblocks,
        cfg.steps,
        cfg.lr
    );
    let t0 = std::time::Instant::now();
    let (tr, top1, top5) =
        common::train_and_eval(&engine, cfg.model, masks, &train, &test, &train_cfg(&cfg), Some(&log))?;
    println!(
        "done in {:.1}s: top1={:.4} top5={:.4} (loss {:.4} → {:.4}); curve: {}",
        t0.elapsed().as_secs_f64(),
        top1,
        top5,
        tr.history.first().map(|p| p.loss).unwrap_or(f32::NAN),
        tr.history.last().map(|p| p.loss).unwrap_or(f32::NAN),
        log.display()
    );
    let ckpt = dir.join(format!("{}_k{}.mpdc", cfg.model.name(), cfg.nblocks));
    tr.save(&ckpt)?;
    println!("checkpoint: {}", ckpt.display());
    Ok(())
}

/// Post-training int8 quantization: (quick-train or load) a masked model,
/// emit the f32 packed artifact and the checkpoint-v2 int8 artifact, verify
/// the int8 file round-trips bit-exactly, and report compression + accuracy.
fn cmd_quantize(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::compress::compressor::MpdCompressor;
    use mpdc::mask::prng::Xoshiro256pp;
    use mpdc::nn::checkpoint;
    use mpdc::nn::mlp::Mlp;
    use mpdc::quant::{calibrate_chunked, QuantizedMlp};
    use mpdc::train::native_trainer::{evaluate_packed, evaluate_quantized, fit_native};

    let cfg = cfg_from_flags(flags)?;
    let dir = out_dir(flags);
    std::fs::create_dir_all(&dir)?;
    let plan = cfg.model.plan(cfg.nblocks).map_err(|e| anyhow::anyhow!(e))?;
    let comp = MpdCompressor::new(plan, cfg.seed);
    let in_dim = comp.plan.layers[0].in_dim;
    let (train, test) = common::make_datasets(cfg.model, cfg.train_samples, cfg.test_samples, cfg.seed);
    anyhow::ensure!(
        train.feature_dim == in_dim,
        "dataset features {} != model input {in_dim}",
        train.feature_dim
    );

    // 1) Trained f32 weights: --ckpt (fc{i}.w / fc{i}.b) or quick native training.
    let (weights, biases) = if let Some(path) = flags.get("ckpt") {
        mpdc::log_info!(
            "quantize",
            "loading {path} (model {}, {} blocks, seed {})…",
            cfg.model.name(),
            cfg.nblocks,
            cfg.seed
        );
        load_mlp_params(&comp, std::path::Path::new(path))?
    } else {
        mpdc::log_info!(
            "quantize",
            "no --ckpt given: training {} natively ({} steps, {} blocks)…",
            cfg.model.name(),
            cfg.steps,
            cfg.nblocks
        );
        let dims: Vec<usize> = std::iter::once(in_dim)
            .chain(comp.plan.layers.iter().map(|l| l.out_dim))
            .collect();
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xA5);
        let mut mlp = Mlp::new(&dims, &mut rng).with_masks(comp.masks.clone());
        let tc = train_cfg(&cfg);
        fit_native(&mut mlp, &train, 50, &tc);
        (
            mlp.layers.iter().map(|l| l.w.clone()).collect::<Vec<_>>(),
            mlp.layers.iter().map(|l| l.b.clone()).collect::<Vec<_>>(),
        )
    };

    // 2) The f32 packed artifact (the compression baseline on disk).
    let packed = comp.build_engine(&weights, &biases, &cfg.engine).map_err(|e| anyhow::anyhow!(e))?;
    let stem = format!("{}_k{}", cfg.model.name(), cfg.nblocks);
    let f32_path = dir.join(format!("{stem}.packed.mpdc"));
    checkpoint::save(&f32_path, &comp.packed_f32_tensors(&weights, &biases))?;

    // 3) Calibrate on training activations, quantize, emit checkpoint v2.
    let nsamples = cfg.quant.calib_samples.min(train.len());
    mpdc::log_info!("quantize", "calibrating on {nsamples} samples (batch {})…", cfg.quant.calib_batch);
    let calib = calibrate_chunked(
        &comp,
        &weights,
        &biases,
        &train.x[..nsamples * in_dim],
        nsamples,
        cfg.quant.calib_batch,
    );
    let q = comp
        .build_quantized_engine(&weights, &biases, &calib, &cfg.engine)
        .map_err(|e| anyhow::anyhow!(e))?;
    let i8_path = dir.join(format!("{stem}.int8.mpdc"));
    checkpoint::save(&i8_path, &q.to_tensors())?;

    // 4) The artifact must round-trip bit-exactly before we report success.
    let back = QuantizedMlp::from_tensors(&comp, &checkpoint::load(&i8_path)?)
        .map_err(|e| anyhow::anyhow!(e))?;
    let probe = 8.min(test.len());
    anyhow::ensure!(
        q.forward(&test.x[..probe * in_dim], probe) == back.forward(&test.x[..probe * in_dim], probe),
        "int8 artifact round-trip mismatch"
    );

    // 5) Report: artifact sizes, compression ratio, accuracy delta.
    let f32_bytes = std::fs::metadata(&f32_path)?.len();
    let i8_bytes = std::fs::metadata(&i8_path)?.len();
    let ratio = f32_bytes as f64 / i8_bytes as f64;
    let acc_f32 = evaluate_packed(&packed, &test, 64);
    let acc_i8 = evaluate_quantized(&q, &test, 64);
    let mut t = Table::new(&["artifact", "format", "bytes", "top-1"]);
    t.row(&[
        f32_path.display().to_string(),
        "v1 f32 packed".into(),
        f32_bytes.to_string(),
        format!("{acc_f32:.4}"),
    ]);
    t.row(&[
        i8_path.display().to_string(),
        "v2 int8 + scales".into(),
        i8_bytes.to_string(),
        format!("{acc_i8:.4}"),
    ]);
    println!("{}", t.render());
    println!(
        "artifact compression: {ratio:.2}× ({f32_bytes} → {i8_bytes} bytes){}",
        if ratio < 3.5 { "  [below the 3.5× target]" } else { "" }
    );
    println!("accuracy delta (int8 − f32): {:+.4}", acc_i8 - acc_f32);
    println!("round-trip: verified bit-exact on {probe} probe samples");
    mpdc::util::json::append_jsonl(
        std::path::Path::new("results/quantize.jsonl"),
        &Json::obj(vec![
            ("model", Json::str(cfg.model.name())),
            ("nblocks", Json::num(cfg.nblocks as f64)),
            ("f32_bytes", Json::num(f32_bytes as f64)),
            ("int8_bytes", Json::num(i8_bytes as f64)),
            ("ratio", Json::num(ratio)),
            ("acc_f32", Json::num(acc_f32)),
            ("acc_int8", Json::num(acc_i8)),
            ("calib_samples", Json::num(nsamples as f64)),
        ]),
    )?;
    Ok(())
}

/// Load `fc{i}.w` / `fc{i}.b` tensors (the `Mlp::named_params` layout) and
/// re-apply the plan's masks, so a checkpoint trained under different masks
/// cannot silently leak off-block weights into packing.
fn load_mlp_params(
    comp: &mpdc::compress::compressor::MpdCompressor,
    path: &std::path::Path,
) -> anyhow::Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
    let tensors = mpdc::nn::checkpoint::load(path)?;
    let find = |name: &str| {
        tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor {name}"))
    };
    let mut weights = Vec::new();
    let mut biases = Vec::new();
    for (i, lp) in comp.plan.layers.iter().enumerate() {
        let w = find(&format!("fc{i}.w"))?;
        anyhow::ensure!(
            w.shape == vec![lp.out_dim, lp.in_dim],
            "fc{i}.w: shape {:?} != [{}, {}]",
            w.shape,
            lp.out_dim,
            lp.in_dim
        );
        let wv = w.as_f32().ok_or_else(|| anyhow::anyhow!("fc{i}.w is not f32"))?.to_vec();
        let wv = match &comp.masks[i] {
            Some(m) => m.apply(&wv),
            None => wv,
        };
        let b = find(&format!("fc{i}.b"))?;
        anyhow::ensure!(b.shape == vec![lp.out_dim], "fc{i}.b: shape {:?} != [{}]", b.shape, lp.out_dim);
        weights.push(wv);
        biases.push(b.as_f32().ok_or_else(|| anyhow::anyhow!("fc{i}.b is not f32"))?.to_vec());
    }
    Ok((weights, biases))
}

/// Dump a model's compiled execution plan: lower the model (structure only —
/// weight *values* never change op shapes, MACs, or storage, so
/// deterministic random masked weights stand in for trained ones) and print
/// the op list with per-sample buffer shapes, activation-buffer bytes at
/// `--batch`, MAC and storage accounting.
fn cmd_plan(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::compress::compressor::MpdCompressor;
    use mpdc::compress::ConvCompressor;
    use mpdc::exec::Precision;
    use mpdc::quant::{Calibration, QuantizedMlp};

    let cfg = cfg_from_flags(flags)?;
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(32);
    anyhow::ensure!(batch >= 1, "--batch must be ≥ 1");
    let precision = flags.get("precision").map(String::as_str).unwrap_or("f32");

    let comp = MpdCompressor::new(cfg.model.plan(cfg.nblocks).map_err(|e| anyhow::anyhow!(e))?, cfg.seed);
    let (weights, biases) = comp.random_masked_weights(cfg.seed);
    let n = comp.nlayers();
    // Unit-range scales: plan structure is scale-independent, so the dump
    // needs no calibration data.
    let cal = Calibration::unit_range(n);
    let (label, exec) = match precision {
        "f32" => {
            let engine = mpdc::compress::PackedMlp::build(&comp, &weights, &biases);
            ("f32 packed", engine.into_executor())
        }
        "int8" => {
            let engine = QuantizedMlp::quantize(&comp, &weights, &biases, &cal)
                .map_err(|e| anyhow::anyhow!(e))?;
            ("int8 packed", engine.into_executor())
        }
        "mixed" => {
            // The natural per-layer policy: int8 for the big masked layers,
            // f32 for dense (head) layers.
            let prec: Vec<Precision> = comp
                .masks
                .iter()
                .map(|m| if m.is_some() { Precision::I8 } else { Precision::F32 })
                .collect();
            let exec = comp
                .build_mixed_engine(&weights, &biases, Some(&cal), &prec, &cfg.engine)
                .map_err(|e| anyhow::anyhow!(e))?;
            ("mixed f32/int8", exec)
        }
        other => anyhow::bail!("unknown --precision {other:?} (f32|int8|mixed)"),
    };
    let exec = maybe_autotune(exec, &cfg);
    // Executor-level describe: adds the per-op kernel column + dispatch
    // summary on top of the structural plan dump.
    println!(
        "== {} · {} blocks · {} precision ==\n{}\n",
        cfg.model.name(),
        cfg.nblocks,
        label,
        exec.describe(batch)
    );

    // Conv-family models (deep_mnist, alexnet, tinyresnet) also have the
    // compressed-conv variant the server registers: dump its plan alongside
    // the FC one, at the same precision.
    if let Some(cplan) = cfg.model.conv_plan(cfg.nblocks) {
        let conv_comp = ConvCompressor::new(cplan, cfg.seed);
        let params = conv_comp.random_masked_params(cfg.seed);
        let conv_exec = maybe_autotune(build_conv_executor(&conv_comp, &params, precision)?, &cfg);
        println!(
            "== {} (compressed conv) · {} blocks ==\n{}",
            conv_plan_label(cfg.model),
            cfg.nblocks,
            conv_exec.describe(batch)
        );
        // Paper/report-scale accounting (structure only — the 224×224 AlexNet
        // is never lowered or trained on this testbed): per-layer compression
        // of the full-size conv stack + FC head.
        if let Some(paper) = cfg.model.paper_conv_plan(cfg.nblocks) {
            let report = ConvCompressor::new(paper, cfg.seed).report();
            let mut t = Table::new(&["layer", "dense params", "kept", "compression"]);
            for l in &report.layers {
                t.row(&[
                    l.name.clone(),
                    l.dense_params.to_string(),
                    l.kept_params.to_string(),
                    format!("{:.2}×", l.compression),
                ]);
            }
            println!(
                "== {} (paper-scale accounting) ==\n{}overall: {} → {} params ({:.2}×)\n",
                cfg.model.name(),
                t.render(),
                report.total_dense_params(),
                report.total_kept_params(),
                report.overall_compression()
            );
        }
    }
    Ok(())
}

/// Section label for a conv-family model's compressed-conv plan dump
/// ("deep-mnist-lite" predates the alexnet/tinyresnet scenarios and is kept
/// for `results/PROF_8.json` key stability). Labels must differ from the
/// model's FC-plan name — both sections land in PROF_8.json under the same
/// (precision, nblocks, batch), so a shared name would merge one entry away.
fn conv_plan_label(model: ModelKind) -> &'static str {
    match model {
        ModelKind::DeepMnist => "deep-mnist-lite",
        ModelKind::Alexnet => "alexnet-lite",
        ModelKind::TinyResnet => "tinyresnet-conv",
        _ => "conv",
    }
}

/// Lower a compressed conv net at the requested CLI precision. "mixed" uses
/// the mask-driven policy (masked stages → int8, dense stages → f32); both
/// quantized paths calibrate with unit-range scales since plan *structure*
/// is scale-independent.
fn build_conv_executor(
    conv_comp: &mpdc::compress::ConvCompressor,
    params: &mpdc::compress::conv_model::ConvNetParams,
    precision: &str,
) -> anyhow::Result<mpdc::exec::Executor> {
    use mpdc::compress::conv_model::PackedConvNet;
    use mpdc::quant::{ConvCalibration, QuantizedConvNet};

    let ccal = || ConvCalibration::unit_range(conv_comp.plan.convs.len(), conv_comp.fc.nlayers());
    Ok(match precision {
        "int8" => QuantizedConvNet::quantize(conv_comp, params, &ccal())
            .map_err(|e| anyhow::anyhow!(e))?
            .into_executor(),
        "mixed" => QuantizedConvNet::quantize_mixed(conv_comp, params, &ccal())
            .map_err(|e| anyhow::anyhow!(e))?
            .into_executor(),
        _ => PackedConvNet::build(conv_comp, params)?.into_executor(),
    })
}

/// Run a compiled plan under the per-op profiler and report where the
/// nanoseconds go. Lowers the model exactly like `mpdc plan` (op timing
/// structure never depends on trained weight *values*, so deterministic
/// random masked weights stand in), warms the arena outside the measured
/// window, times `--iters` batched runs, and prints per-op calls /
/// total / mean / min / max time, wall-time share, and effective GFLOP/s
/// and GB/s from the plan's MAC/byte accounting. Per-op totals must
/// attribute ≥ 90% of the end-to-end wall time (warns otherwise); every
/// section is merged into `results/PROF_8.json`.
fn cmd_profile(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::compress::compressor::MpdCompressor;
    use mpdc::compress::ConvCompressor;
    use mpdc::exec::{kernel_label, Precision, ScratchArena};
    use mpdc::mask::prng::Xoshiro256pp;
    use mpdc::quant::{Calibration, QuantizedMlp};

    let cfg = cfg_from_flags(flags)?;
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let iters: usize = flags.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(50);
    anyhow::ensure!(batch >= 1, "--batch must be ≥ 1");
    anyhow::ensure!(iters >= 1, "--iters must be ≥ 1");
    let precision = flags.get("precision").map(String::as_str).unwrap_or("f32");

    let comp = MpdCompressor::new(cfg.model.plan(cfg.nblocks).map_err(|e| anyhow::anyhow!(e))?, cfg.seed);
    let (weights, biases) = comp.random_masked_weights(cfg.seed);
    let cal = Calibration::unit_range(comp.nlayers());
    let exec = match precision {
        "f32" => mpdc::compress::PackedMlp::build(&comp, &weights, &biases).into_executor(),
        "int8" => QuantizedMlp::quantize(&comp, &weights, &biases, &cal)
            .map_err(|e| anyhow::anyhow!(e))?
            .into_executor(),
        "mixed" => {
            let prec: Vec<Precision> = comp
                .masks
                .iter()
                .map(|m| if m.is_some() { Precision::I8 } else { Precision::F32 })
                .collect();
            comp.build_mixed_engine(&weights, &biases, Some(&cal), &prec, &cfg.engine)
                .map_err(|e| anyhow::anyhow!(e))?
        }
        other => anyhow::bail!("unknown --precision {other:?} (f32|int8|mixed)"),
    };
    let exec = maybe_autotune(exec, &cfg);
    let mut sections = vec![(cfg.model.name().to_string(), exec)];

    // The server's conv-mpd variants (deep-mnist-mpd, alexnet-mpd,
    // tinyresnet-mpd) run the compressed-conv plan: profile it alongside the
    // FC one, like `mpdc plan` dumps both.
    if let Some(cplan) = cfg.model.conv_plan(cfg.nblocks) {
        let conv_comp = ConvCompressor::new(cplan, cfg.seed);
        let params = conv_comp.random_masked_params(cfg.seed);
        let conv_exec = maybe_autotune(build_conv_executor(&conv_comp, &params, precision)?, &cfg);
        sections.push((conv_plan_label(cfg.model).to_string(), conv_exec));
    }

    let mut entries: Vec<Json> = Vec::new();
    for (plan_name, exec) in sections {
        let exec = exec.with_profiling();
        let profile = exec.profile().expect("profiling just enabled").clone();
        let (in_dim, out_dim) = (exec.in_dim(), exec.out_dim());
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.next_f32()).collect();
        let mut y = vec![0.0f32; batch * out_dim];
        let mut scratch = ScratchArena::new();
        // Warm-up outside the measured window: arena growth, pool spin-up,
        // and first-touch page faults would otherwise be billed to op 0.
        for _ in 0..3 {
            exec.run_into(&x, batch, &mut y, &mut scratch);
        }
        profile.reset();
        let wall_t0 = std::time::Instant::now();
        for _ in 0..iters {
            exec.run_into(&x, batch, &mut y, &mut scratch);
        }
        let wall_ns = wall_t0.elapsed().as_nanos() as u64;
        mpdc::util::benchkit::black_box(&y);

        let attributed = profile.attributed_ns();
        let attribution = attributed as f64 / wall_ns.max(1) as f64;
        let mut t = Table::new(&[
            "#", "op", "kernel", "calls", "total ms", "mean µs", "min µs", "max µs", "share %",
            "GFLOP/s", "GB/s",
        ]);
        for r in &profile.rows() {
            t.row(&[
                r.index.to_string(),
                r.name.to_string(),
                kernel_label(&exec.plan().ops[r.index].op, &exec.kernel()).to_string(),
                r.calls.to_string(),
                format!("{:.3}", r.total_ns as f64 / 1e6),
                format!("{:.1}", r.mean_ns() / 1e3),
                format!("{:.1}", r.min_ns as f64 / 1e3),
                format!("{:.1}", r.max_ns as f64 / 1e3),
                format!("{:.1}", 100.0 * r.total_ns as f64 / attributed.max(1) as f64),
                format!("{:.2}", r.gflops),
                format!("{:.2}", r.gbytes_per_s),
            ]);
        }
        println!(
            "== {plan_name} · {} blocks · {precision} · batch {batch} · {iters} iters ==\n{}",
            cfg.nblocks,
            t.render()
        );
        println!(
            "wall {:.3} ms  attributed {:.3} ms ({:.1}%)  {:.1} µs/run  {:.0} samples/s\n",
            wall_ns as f64 / 1e6,
            attributed as f64 / 1e6,
            attribution * 100.0,
            wall_ns as f64 / 1e3 / iters as f64,
            (iters * batch) as f64 * 1e9 / wall_ns.max(1) as f64,
        );
        if attribution < 0.9 {
            mpdc::log_warn!(
                "profile",
                "{plan_name}: per-op totals attribute only {:.1}% of wall time (want ≥ 90%)",
                attribution * 100.0
            );
        }
        entries.push(Json::obj(vec![
            ("plan", Json::str(plan_name.as_str())),
            ("precision", Json::str(precision)),
            ("nblocks", Json::num(cfg.nblocks as f64)),
            ("batch", Json::num(batch as f64)),
            ("iters", Json::num(iters as f64)),
            ("wall_ns", Json::num(wall_ns as f64)),
            ("attribution", Json::num(attribution)),
            ("profile", profile.to_json()),
        ]));
    }
    let path = merge_prof_results(&entries)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Merge profile entries into `results/PROF_8.json`, keyed by
/// (plan, precision, nblocks, batch): repeated CLI runs update their own
/// entry in place instead of clobbering the rest of the file.
fn merge_prof_results(new_entries: &[Json]) -> anyhow::Result<PathBuf> {
    let path = mpdc::util::benchkit::results_dir().join("PROF_8.json");
    let entry_key = |e: &Json| -> String {
        format!(
            "{}|{}|{}|{}",
            e.get("plan").and_then(Json::as_str).unwrap_or(""),
            e.get("precision").and_then(Json::as_str).unwrap_or(""),
            e.get("nblocks").and_then(Json::as_f64).unwrap_or(-1.0),
            e.get("batch").and_then(Json::as_f64).unwrap_or(-1.0),
        )
    };
    let mut entries: Vec<Json> = match std::fs::read_to_string(&path) {
        Ok(text) => Json::parse(&text)
            .ok()
            .and_then(|j| j.get("entries").and_then(|e| e.as_arr().map(<[Json]>::to_vec)))
            .unwrap_or_default(),
        Err(_) => Vec::new(),
    };
    for new in new_entries {
        let key = entry_key(new);
        entries.retain(|e| entry_key(e) != key);
        entries.push(new.clone());
    }
    let doc = Json::obj(vec![("bench", Json::str("profile")), ("entries", Json::Arr(entries))]);
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

fn cmd_serve(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::compress::compressor::MpdCompressor;
    use mpdc::compress::plan::{LayerPlan, SparsityPlan};
    use mpdc::data::dataset::Dataset;
    use mpdc::data::synth::{SynthImages, SynthSpec};
    use mpdc::linalg::csr::Csr;
    use mpdc::mask::prng::Xoshiro256pp;
    use mpdc::nn::mlp::Mlp;
    use mpdc::quant::calibrate_chunked;
    use mpdc::exec::{lower_dense_mlp, Executor};
    use mpdc::server::{spawn, CsrBackend, HttpServer, PlanBackend, Router};
    use mpdc::train::native_trainer::fit_native;
    use std::sync::Arc;

    let mut cfg = cfg_from_flags(flags)?;
    if let Some(p) = flags.get("port") {
        cfg.server.port = p.parse()?;
    }
    if let Some(m) = flags.get("serve-mode") {
        cfg.server.mode = m.clone();
        cfg.server.validate().map_err(|e| anyhow::anyhow!(e))?;
    }
    let steps: usize = flags.get("steps").map(|s| s.parse()).transpose()?.unwrap_or(150);
    // [obs]: seed the log-level default (MPDC_LOG still wins) and size the
    // span rings before any server thread claims a ring slot.
    cfg.obs.apply();

    // Quick native training on synthetic MNIST-like data: enough to make the
    // three representations meaningfully identical, fast enough for a CLI.
    mpdc::log_info!("serve", "training masked LeNet-300-100 natively ({steps} steps, {} blocks)…", cfg.nblocks);
    let spec = SynthSpec::mnist_like();
    let mut train = Dataset::from_synth(&SynthImages::generate(spec, 1500, cfg.seed, 0));
    train.normalize();
    let comp = MpdCompressor::new(SparsityPlan::lenet300(cfg.nblocks), cfg.seed);
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xA5);
    let mut mlp = Mlp::new(&[784, 300, 100, 10], &mut rng).with_masks(comp.masks.clone());
    let tc = TrainConfig { steps, lr: 0.08, log_every: (steps / 4).max(1), seed: cfg.seed, ..Default::default() };
    fit_native(&mut mlp, &train, 50, &tc);

    // Three serving representations of the same trained weights.
    let weights: Vec<Vec<f32>> = mlp.layers.iter().map(|l| l.w.clone()).collect();
    let biases: Vec<Vec<f32>> = mlp.layers.iter().map(|l| l.b.clone()).collect();
    let packed = comp.build_engine(&weights, &biases, &cfg.engine).map_err(|e| anyhow::anyhow!(e))?;
    let csr_layers: Vec<(Csr, Vec<f32>)> = weights
        .iter()
        .zip(&biases)
        .zip(&comp.plan.layers)
        .map(|((w, b), lp)| (Csr::from_dense(w, lp.out_dim, lp.in_dim), b.clone()))
        .collect();

    // Every model variant serves through the one generic PlanBackend: the
    // dense baseline is lowered to a plan too, so all four representations
    // run on the same interpreter with per-worker arenas.
    let bc = cfg.server.batcher_config();
    // [obs] profiling=true (the default) builds every plan-backed variant
    // with a live per-op profile, surfaced at GET /debug/profile.
    let with_obs = |b: PlanBackend| if cfg.obs.profiling { b.profiled() } else { b };
    let mut router = Router::new();
    let (h, _w1) = spawn(with_obs(PlanBackend::new(Executor::new(lower_dense_mlp(&mlp)))).with_max_batch(bc.max_batch).warmed(), bc);
    router.register("dense", h);
    let (h, _w2) = spawn(CsrBackend { layers: csr_layers, feature_dim: 784, out_dim: 10 }, bc);
    router.register("csr", h);
    let (h, _w3) = spawn(with_obs(PlanBackend::new(packed.into_executor())).with_max_batch(bc.max_batch).warmed(), bc);
    router.register("mpd", h);

    // Quantized -int8 variants of the same trained weights ([quant] in TOML):
    // mpd-int8 runs the block-diagonal i8 engine, dense-int8 the same weights
    // through an all-dense plan — both calibrated on the training activations.
    if cfg.quant.enabled {
        let nsamples = cfg.quant.calib_samples.min(train.len());
        let calib_x = &train.x[..nsamples * 784];
        let calib =
            calibrate_chunked(&comp, &weights, &biases, calib_x, nsamples, cfg.quant.calib_batch);
        let q = comp
            .build_quantized_engine(&weights, &biases, &calib, &cfg.engine)
            .map_err(|e| anyhow::anyhow!(e))?;
        let (h, _wq1) = spawn(with_obs(PlanBackend::new(q.into_executor())).with_max_batch(bc.max_batch).warmed(), bc);
        router.register("mpd-int8", h);

        let dense_plan = SparsityPlan::new(vec![
            LayerPlan::dense("fc1", 300, 784),
            LayerPlan::dense("fc2", 100, 300),
            LayerPlan::dense("fc3", 10, 100),
        ])
        .map_err(|e| anyhow::anyhow!(e))?;
        let dense_comp = MpdCompressor::new(dense_plan, cfg.seed);
        // calibration depends only on layer dims + weights (never on masks),
        // so the scales computed for mpd-int8 are exactly right here too
        let qd = dense_comp
            .build_quantized_engine(&weights, &biases, &calib, &cfg.engine)
            .map_err(|e| anyhow::anyhow!(e))?;
        let (h, _wq2) = spawn(with_obs(PlanBackend::new(qd.into_executor())).with_max_batch(bc.max_batch).warmed(), bc);
        router.register("dense-int8", h);
    }

    // Compressed-conv variants ([conv] in TOML): quick-train a conv net
    // under in-training masking (masked filter matrices + head FC layers
    // carry MPD masks), lower it via im2col onto the packed block-diagonal
    // engine, and register its `<name>-mpd` variant (+ the `-int8` twin when
    // [quant] is also enabled). deep-mnist-mpd is always registered;
    // `--model alexnet` / `--model tinyresnet` additionally register their
    // own strided/grouped (resp. residual + avg-pool) conv plans.
    if cfg.conv.enabled {
        use mpdc::compress::conv_model::ConvNetParams;
        use mpdc::compress::{ConvCompressor, ConvModelPlan};
        use mpdc::quant::{calibrate_conv, QuantizedConvNet};
        use mpdc::train::native_trainer::fit_native_conv;

        let mut register_conv = |router: &mut Router,
                                 variant: &'static str,
                                 cplan: ConvModelPlan,
                                 data: &Dataset,
                                 seed_salt: u64|
         -> anyhow::Result<()> {
            mpdc::log_info!(
                "serve",
                "training {variant} conv net natively ({} steps, {} blocks)…",
                cfg.conv.steps,
                cfg.nblocks
            );
            let conv_comp = ConvCompressor::new(cplan, cfg.seed);
            let mut conv_rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ seed_salt);
            let mut conv_net = conv_comp.build_net(&mut conv_rng);
            let ctc = TrainConfig {
                steps: cfg.conv.steps,
                lr: 0.05,
                log_every: (cfg.conv.steps / 4).max(1),
                seed: cfg.seed,
                ..Default::default()
            };
            fit_native_conv(&mut conv_net, data, 32, &ctc);
            let cparams = ConvNetParams::from_net(&conv_net);
            let cr = conv_comp.report();
            mpdc::log_info!(
                "serve",
                "{variant}: {:.2}× parameter compression ({} → {})",
                cr.overall_compression(),
                cr.total_dense_params(),
                cr.total_kept_params()
            );
            let cpacked = conv_comp.build_engine(&cparams, &cfg.engine).map_err(|e| anyhow::anyhow!(e))?;
            let (h, _wc) = spawn(with_obs(PlanBackend::new(cpacked.into_executor())).with_max_batch(bc.max_batch).warmed(), bc);
            router.register(variant, h);

            if cfg.quant.enabled {
                let nsamples = cfg.quant.calib_samples.min(data.len());
                let ccalib = calibrate_conv(
                    &conv_comp,
                    &cparams,
                    &data.x[..nsamples * data.feature_dim],
                    nsamples,
                    cfg.quant.calib_batch,
                );
                let cq = QuantizedConvNet::quantize(&conv_comp, &cparams, &ccalib)
                    .map_err(|e| anyhow::anyhow!(e))?
                    .with_engine_config(&cfg.engine)
                    .map_err(|e| anyhow::anyhow!(e))?;
                let (h, _wcq) = spawn(with_obs(PlanBackend::new(cq.into_executor())).with_max_batch(bc.max_batch).warmed(), bc);
                let name: &'static str = match variant {
                    "deep-mnist-mpd" => "deep-mnist-mpd-int8",
                    "alexnet-mpd" => "alexnet-mpd-int8",
                    "tinyresnet-mpd" => "tinyresnet-mpd-int8",
                    _ => unreachable!("unknown conv variant {variant}"),
                };
                router.register(name, h);
            }
            Ok(())
        };

        anyhow::ensure!(cfg.nblocks <= 256, "deep-mnist-mpd supports ≤ 256 blocks");
        register_conv(&mut router, "deep-mnist-mpd", ConvModelPlan::deep_mnist_lite(cfg.nblocks), &train, 0xC4)?;

        if let (Some(variant), Some(cplan)) = (cfg.model.conv_variant(), cfg.model.conv_plan(cfg.nblocks)) {
            if variant != "deep-mnist-mpd" {
                // conv-first models train on the ImageNet-like 3×32×32 synth
                // set (16 classes), not the flat MNIST-like one
                let mut ctrain =
                    Dataset::from_synth(&SynthImages::generate(SynthSpec::imagenet_like(16), 600, cfg.seed, 2));
                ctrain.normalize();
                register_conv(&mut router, variant, cplan, &ctrain, 0xC7)?;
            }
        }
    }

    if let Some(split) = flags.get("split") {
        let parsed: Vec<(String, f64)> = split
            .split(',')
            .map(|pair| {
                let (name, w) = pair
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("bad --split entry {pair:?} (want name:weight)"))?;
                Ok((name.trim().to_string(), w.trim().parse::<f64>()?))
            })
            .collect::<anyhow::Result<_>>()?;
        let as_refs: Vec<(&str, f64)> = parsed.iter().map(|(n, w)| (n.as_str(), *w)).collect();
        router.set_split(&as_refs).map_err(|e| anyhow::anyhow!(e))?;
        mpdc::log_info!("serve", "weighted split: {split}");
    }

    let variants = router.variant_names().join("/");
    let hc = cfg.server.http_config();
    let mode_name = hc.mode.name();
    let server = HttpServer::start(Arc::new(router), hc)?;
    println!("serving {variants} on {} ({mode_name} front-end)", server.url());
    println!("  curl {}/healthz", server.url());
    println!("  curl {}/variants", server.url());
    println!("  curl {}/metrics", server.url());
    println!("  curl -X POST {}/infer/mpd -d '{{\"input\":[0.0, …×784]}}'", server.url());
    println!("  mpdc loadgen --port {} --variant mpd", server.addr().port());
    server.join();
    Ok(())
}

fn cmd_loadgen(flags: &Flags) -> anyhow::Result<()> {
    use mpdc::server::loadgen::{self, Arrival, LoadgenConfig, SweepConfig};
    use std::net::ToSocketAddrs;

    let host = flags.get("host").map(String::as_str).unwrap_or("127.0.0.1");
    let port: u16 = flags.get("port").map(|s| s.parse()).transpose()?.unwrap_or(8077);
    let addr = format!("{host}:{port}")
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("cannot resolve {host}:{port}"))?;
    let variant = flags.get("variant").cloned().unwrap_or_else(|| "mpd".into());
    let mode = flags.get("mode").map(String::as_str).unwrap_or("closed");
    let qps: f64 = flags.get("qps").map(|s| s.parse()).transpose()?.unwrap_or(500.0);

    fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> anyhow::Result<Vec<T>> {
        s.split(',')
            .map(|v| v.trim().parse::<T>().map_err(|_| anyhow::anyhow!("bad {what} entry {v:?}")))
            .collect()
    }

    if mode == "sweep" {
        // Open-loop sweep over a grid of offered loads: the latency-vs-load
        // curve behind results/BENCH_7.json, driven manually.
        let mut sweep_cfg = SweepConfig::default();
        if let Some(s) = flags.get("qps-points") {
            sweep_cfg.qps_points = parse_list(s, "--qps-points")?;
        }
        if let Some(s) = flags.get("concurrencies") {
            sweep_cfg.concurrencies = parse_list(s, "--concurrencies")?;
        }
        if let Some(s) = flags.get("requests") {
            sweep_cfg.requests_per_point = s.parse()?;
        }
        if let Some(s) = flags.get("seed") {
            sweep_cfg.seed = s.parse()?;
        }
        let variants = loadgen::discover_variants(addr).map_err(|e| anyhow::anyhow!(e))?;
        let Some((_, feature_dim, _)) = variants.iter().find(|(n, _, _)| *n == variant) else {
            anyhow::bail!(
                "variant {variant:?} not served (have: {})",
                variants.iter().map(|(n, _, _)| n.as_str()).collect::<Vec<_>>().join(", ")
            );
        };
        mpdc::log_info!("loadgen", "sweeping open load at http://{addr}/infer/{variant} ({feature_dim} features)…");
        let points = loadgen::sweep(addr, &variant, *feature_dim, &sweep_cfg);
        let mut t = Table::new(&[
            "conc", "offered q/s", "achieved q/s", "sent", "ok", "non-200 %", "p50 µs", "p99 µs",
            "non-200 p99 µs",
        ]);
        for p in &points {
            t.row(&[
                p.concurrency.to_string(),
                format!("{:.0}", p.offered_qps),
                format!("{:.0}", p.achieved_rps),
                p.sent.to_string(),
                p.ok.to_string(),
                format!("{:.2}", p.non_200_rate * 100.0),
                format!("{:.0}", p.p50_us),
                format!("{:.0}", p.p99_us),
                format!("{:.0}", p.non200_p99_us),
            ]);
            mpdc::util::json::append_jsonl(
                std::path::Path::new("results/serve_loadgen.jsonl"),
                &Json::obj(vec![
                    ("variant", Json::str(variant.as_str())),
                    ("mode", Json::str("sweep")),
                    ("concurrency", Json::num(p.concurrency as f64)),
                    ("offered_qps", Json::num(p.offered_qps)),
                    ("achieved_rps", Json::num(p.achieved_rps)),
                    ("sent", Json::num(p.sent as f64)),
                    ("ok", Json::num(p.ok as f64)),
                    ("non200_rate", Json::num(p.non_200_rate)),
                    ("p50_us", Json::num(p.p50_us)),
                    ("p99_us", Json::num(p.p99_us)),
                    ("non200_p99_us", Json::num(p.non200_p99_us)),
                ]),
            )?;
        }
        println!("{}", t.render());
        return Ok(());
    }

    let arrival = match mode {
        "closed" => Arrival::Closed,
        "open" => Arrival::Poisson { target_qps: qps },
        other => anyhow::bail!("unknown --mode {other:?} (closed|open|sweep)"),
    };
    let cfg = LoadgenConfig {
        concurrency: flags.get("concurrency").map(|s| s.parse()).transpose()?.unwrap_or(4),
        requests: flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(2000),
        arrival,
        seed: flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42),
    };

    let variants = loadgen::discover_variants(addr).map_err(|e| anyhow::anyhow!(e))?;
    let Some((_, feature_dim, _)) = variants.iter().find(|(n, _, _)| *n == variant) else {
        anyhow::bail!(
            "variant {variant:?} not served (have: {})",
            variants.iter().map(|(n, _, _)| n.as_str()).collect::<Vec<_>>().join(", ")
        );
    };
    mpdc::log_info!("loadgen", "driving {mode} load at http://{addr}/infer/{variant} ({} features)…", feature_dim);
    let report = loadgen::run_http(addr, &variant, *feature_dim, &cfg);
    let mut t = Table::new(&[
        "variant", "mode", "sent", "ok", "429", "err", "req/s", "p50 µs", "p90 µs", "p99 µs",
        "non-200 p99 µs",
    ]);
    t.row(&[
        variant.clone(),
        mode.to_string(),
        report.sent.to_string(),
        report.ok.to_string(),
        report.rejected.to_string(),
        report.errors.to_string(),
        format!("{:.0}", report.throughput_rps()),
        format!("{:.0}", report.latency.percentile_us(0.5)),
        format!("{:.0}", report.latency.percentile_us(0.9)),
        format!("{:.0}", report.latency.percentile_us(0.99)),
        format!("{:.0}", report.latency_non200.percentile_us(0.99)),
    ]);
    println!("{}", t.render());
    println!(
        "non-200 rate: {:.2}% (2xx={} 4xx={} 5xx={} transport={})",
        report.non_200_rate() * 100.0,
        report.status_classes[1],
        report.status_classes[3],
        report.status_classes[4],
        report.transport_errors,
    );
    mpdc::util::json::append_jsonl(
        std::path::Path::new("results/serve_loadgen.jsonl"),
        &Json::obj(vec![
            ("variant", Json::str(variant)),
            ("mode", Json::str(mode)),
            ("sent", Json::num(report.sent as f64)),
            ("ok", Json::num(report.ok as f64)),
            ("rejected", Json::num(report.rejected as f64)),
            ("errors", Json::num(report.errors as f64)),
            ("non200_rate", Json::num(report.non_200_rate())),
            ("transport_errors", Json::num(report.transport_errors as f64)),
            ("rps", Json::num(report.throughput_rps())),
            ("p50_us", Json::num(report.latency.percentile_us(0.5))),
            ("p99_us", Json::num(report.latency.percentile_us(0.99))),
            ("non200_p50_us", Json::num(report.latency_non200.percentile_us(0.5))),
            ("non200_p99_us", Json::num(report.latency_non200.percentile_us(0.99))),
        ]),
    )?;
    Ok(())
}

fn cmd_fig1(flags: &Flags) -> anyhow::Result<()> {
    let dir = out_dir(flags);
    let out = figures::fig1(&dir, 42)?;
    println!(
        "fig1: B density {:.3} | M density {:.3} | fraction of M off-block {:.3}",
        out.b_density, out.m_density, out.m_offblock_fraction
    );
    println!("wrote {}/fig1_b.pgm, fig1_m.pgm", dir.display());
    Ok(())
}

fn cmd_fig4a(flags: &Flags) -> anyhow::Result<()> {
    let cfg = cfg_from_flags(flags)?;
    let nmasks: usize = flags.get("masks").map(|s| s.parse()).transpose()?.unwrap_or(10);
    let engine = common::try_engine().ok_or_else(|| anyhow::anyhow!("artifacts missing"))?;
    let out = figures::fig4a(&engine, nmasks, &train_cfg(&cfg), (cfg.train_samples, cfg.test_samples))?;
    let accs: Vec<f64> = out.per_mask.iter().map(|p| p.top1).collect();
    let min = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = accs.iter().cloned().fold(0.0, f64::max);
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    let mut t = Table::new(&["variant", "top-1"]);
    t.row(&[format!("MPD ({} masks) min", accs.len()), format!("{min:.4}")]);
    t.row(&["MPD mean".into(), format!("{mean:.4}")]);
    t.row(&["MPD max".into(), format!("{max:.4}")]);
    t.row(&["dense baseline".into(), format!("{:.4}", out.dense_top1)]);
    t.row(&["non-permuted 10%".into(), format!("{:.4}", out.non_permuted_top1)]);
    t.row(&["non-permuted 20%".into(), format!("{:.4}", out.non_permuted_20_top1)]);
    println!("{}", t.render());
    for p in &out.per_mask {
        common::emit(
            "results/fig4a.jsonl",
            Json::obj(vec![
                ("mask_id", Json::num(p.mask_id as f64)),
                ("seed", Json::num(p.seed as f64)),
                ("top1", Json::num(p.top1)),
            ]),
        );
    }
    Ok(())
}

fn cmd_fig4b(flags: &Flags) -> anyhow::Result<()> {
    let nmasks: usize = flags.get("masks").map(|s| s.parse()).transpose()?.unwrap_or(100);
    let dir = out_dir(flags);
    let out = figures::fig4b(&dir, nmasks, 42)?;
    println!(
        "fig4b ({} masks, 300×100, 10 blocks): mean={:.2} min={} max={} var={:.2} never-covered={:.4}%",
        out.nmasks,
        out.stats.mean,
        out.stats.min,
        out.stats.max,
        out.stats.variance,
        out.stats.never_covered * 100.0
    );
    println!("wrote {}/fig4b_mask_sum.pgm", dir.display());
    Ok(())
}

fn cmd_fig5(flags: &Flags) -> anyhow::Result<()> {
    let cfg = cfg_from_flags(flags)?;
    let engine = common::try_engine().ok_or_else(|| anyhow::anyhow!("artifacts missing"))?;
    let points = figures::fig5(&engine, &[4, 8, 16], &train_cfg(&cfg), (cfg.train_samples, cfg.test_samples))?;
    let mut t = Table::new(&["sparsity", "compression", "top-1", "top-5"]);
    for p in &points {
        let name = if p.nblocks == 0 { "dense".to_string() } else { format!("{:.2}%", p.sparsity_pct) };
        let comp = if p.nblocks == 0 { "1×".to_string() } else { format!("{}×", p.nblocks) };
        t.row(&[name, comp, format!("{:.4}", p.top1), format!("{:.4}", p.top5)]);
        common::emit(
            "results/fig5.jsonl",
            Json::obj(vec![
                ("nblocks", Json::num(p.nblocks as f64)),
                ("top1", Json::num(p.top1)),
                ("top5", Json::num(p.top5)),
            ]),
        );
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_table1(flags: &Flags) -> anyhow::Result<()> {
    let cfg = cfg_from_flags(flags)?;
    let engine = common::try_engine().ok_or_else(|| anyhow::anyhow!("artifacts missing"))?;
    let models = [
        (ModelKind::Lenet300, 10usize),
        (ModelKind::DeepMnist, 10),
        (ModelKind::Cifar10, 10),
        (ModelKind::TinyAlexnet, 8),
    ];
    let rows = table1::table1(&engine, &models, &train_cfg(&cfg), (cfg.train_samples, cfg.test_samples))?;
    let mut t = Table::new(&[
        "model",
        "MPD top1",
        "dense top1",
        "acc loss",
        "FC params MPD",
        "FC params dense",
        "compression",
    ]);
    for r in &rows {
        t.row(&[
            r.model.to_string(),
            format!("{:.4}", r.mpd_top1),
            format!("{:.4}", r.dense_top1),
            format!("{:+.4}", r.accuracy_loss()),
            human_count(r.paper_params_mpd),
            human_count(r.paper_params_dense),
            format!("{:.1}×", r.compression()),
        ]);
        common::emit(
            "results/table1.jsonl",
            Json::obj(vec![
                ("model", Json::str(r.model)),
                ("mpd_top1", Json::num(r.mpd_top1)),
                ("dense_top1", Json::num(r.dense_top1)),
                ("params_mpd", Json::num(r.paper_params_mpd as f64)),
                ("params_dense", Json::num(r.paper_params_dense as f64)),
            ]),
        );
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_speedup(flags: &Flags) -> anyhow::Result<()> {
    let quick = !flags.contains_key("full");
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(32);
    // `[engine]` from --config tunes the packed engine (pool + tile shape)
    let cfg = cfg_from_flags(flags)?;
    let rows = speedup::kernel_sweep(&[4, 8, 10, 16], batch, quick, &cfg.engine);
    let mut t = Table::new(&[
        "layer",
        "blocks",
        "dense µs",
        "CSR µs",
        "blockdiag µs",
        "tuned µs",
        "vs dense",
        "vs CSR",
        "tuned×",
    ]);
    for r in &rows {
        t.row(&[
            r.layer.clone(),
            r.nblocks.to_string(),
            format!("{:.1}", r.dense_us),
            format!("{:.1}", r.csr_us),
            format!("{:.1}", r.blockdiag_us),
            format!("{:.1}", r.tuned_us),
            format!("{:.2}×", r.speedup_vs_dense()),
            format!("{:.2}×", r.speedup_vs_csr()),
            format!("{:.2}×", r.tuned_speedup_vs_dense()),
        ]);
    }
    println!("{}", t.render());
    if let Some(engine) = common::try_engine() {
        let (d, p) = speedup::aot_lenet_comparison(&engine, batch, quick)?;
        println!(
            "AOT lenet b{batch}: dense {:.1}µs vs packed {:.1}µs → {:.2}×",
            d.median_us(),
            p.median_us(),
            d.median_us() / p.median_us()
        );
    }
    Ok(())
}

fn human_count(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}k", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}
