//! # mpdc — MPDCompress in Rust + JAX + Pallas
//!
//! A production-shaped reproduction of *MPDCompress: Matrix Permutation
//! Decomposition Algorithm for Deep Neural Network Compression* (Supic et
//! al., 2018). Fully-connected layers are trained under binary masks that
//! are random row/column permutations of block-diagonal matrices; at
//! inference the inverse permutations (eq. 2) expose an exactly
//! block-diagonal weight matrix, executed as independent dense blocks.
//!
//! Layer map (see DESIGN.md):
//! * [`mask`] — permutations, block layouts, MPD masks, Fig.-1 decomposition
//! * [`linalg`] — dense GEMM, CSR baseline, packed block-diagonal GEMM
//! * [`nn`] — native layers/MLP/conv, checkpoints
//! * [`data`] — synthetic datasets + IDX loader
//! * [`compress`] — plans, compressor, packed inference engine, pruning baseline
//! * [`runtime`] — PJRT loader/executor for AOT JAX artifacts
//! * [`train`] — AOT + native trainers
//! * [`server`] — batching inference server
//! * [`config`] — TOML-subset config system
//! * [`util`] — bench harness, property testing, JSON, PGM, CRC32
pub mod compress;
pub mod runtime;
pub mod train;
pub mod server;
pub mod config;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod mask;
pub mod nn;
pub mod util;
