//! # mpdc — MPDCompress in Rust + JAX + Pallas
//!
//! A production-shaped reproduction of *MPDCompress: Matrix Permutation
//! Decomposition Algorithm for Deep Neural Network Compression* (Supic et
//! al., 2018). Fully-connected layers are trained under binary masks that
//! are random row/column permutations of block-diagonal matrices; at
//! inference the inverse permutations (eq. 2) expose an exactly
//! block-diagonal weight matrix, executed as independent dense blocks.
//!
//! Layer map (see DESIGN.md):
//! * [`mask`] — permutations, block layouts, MPD masks, Fig.-1 decomposition
//! * [`linalg`] — dense GEMM, CSR baseline, the persistent worker pool
//!   (`linalg::pool`), the register-tiled packed block-diagonal GEMM with
//!   fused bias+ReLU epilogue (`linalg::blockdiag_mm`), and the im2col
//!   conv lowering (`linalg::im2col`) that feeds conv layers into it
//! * [`exec`] — the unified execution-plan IR: the op vocabulary
//!   ([`exec::Op`]), compiled plans with buffer/MAC/storage accounting
//!   ([`exec::ExecPlan`]), the preallocated ping-pong
//!   [`exec::ScratchArena`], and the single interpreter
//!   ([`exec::Executor`]) with the zero-allocation `run_into` hot path;
//!   plus the shared MLP lowering incl. per-layer f32/i8 mixed precision
//!   ([`exec::lower_mlp`])
//! * [`nn`] — native layers/MLP/conv layers/trainable conv nets, checkpoints
//! * [`data`] — synthetic datasets + IDX loader
//! * [`compress`] — plans (FC + mixed conv+dense), compressors, and the
//!   packed engine front-ends (`compress::packed_model` for MLPs,
//!   `compress::conv_model` for im2col-lowered conv nets) — thin lowerings
//!   onto [`exec`] — plus the pruning baseline
//! * [`quant`] — post-training int8 quantization: activation calibration,
//!   the i8 engine front-ends (`quant::QuantizedMlp` / `quant::qconv`,
//!   lowering onto the integer kernel in `linalg::blockdiag_mm_i8`), and
//!   the checkpoint-v2 i8 serialization
//! * [`runtime`] — PJRT loader/executor for AOT JAX artifacts (behind the
//!   `pjrt` feature; stubs out gracefully offline)
//! * [`train`] — AOT + native trainers, packed-engine evaluation
//! * [`server`] — serving stack: bounded-queue dynamic batcher, weighted
//!   A/B router, Prometheus metrics, the dependency-free HTTP/1.1 front-end
//!   (`server::http`), and the closed/open-loop load generator
//!   (`server::loadgen`); every compiled model serves through one generic
//!   [`server::PlanBackend`] whose worker reuses a persistent pool *and* a
//!   scratch arena across every batch it executes
//! * [`config`] — TOML-subset config system, incl. [`config::EngineConfig`]
//!   (pool sizing + kernel tile shape), [`config::ServerConfig`]
//!   (`[server]`: HTTP transport + batching policy), and
//!   [`config::ObsConfig`] (`[obs]`: profiling, span rings, log level)
//! * [`obs`] — observability: the `MPDC_LOG`-leveled logger, lock-free
//!   per-thread span rings, and the per-op [`obs::ExecProfile`] filled by
//!   profiling-enabled executors (served live at `GET /debug/profile`)
//! * [`util`] — bench harness, property testing, JSON, PGM, CRC32
//!
//! Engine notes — pool lifecycle, tile-shape choice, and the fusion
//! contract — live in DESIGN.md §Engine; the op taxonomy, arena lifecycle,
//! and lowering contract in DESIGN.md §Execution Plan; batching policy,
//! backpressure/429 semantics, and metric resolution bounds in DESIGN.md
//! §Serving. The repo-level overview (quickstart, architecture map, bench
//! index) is in README.md.
//
// Kernel and epilogue code indexes by position on purpose (canonical
// accumulation order, in-bounds-provable tile offsets), and the fused entry
// points thread pool/tile/epilogue state explicitly; these style lints fight
// both idioms, so they are opted out crate-wide rather than per-function.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::new_without_default)]
pub mod compress;
pub mod exec;
pub mod quant;
pub mod runtime;
pub mod train;
pub mod server;
pub mod config;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod mask;
pub mod nn;
pub mod obs;
pub mod util;
