//! Matrix permutation decomposition — the Fig. 1(a–d) direction of the paper.
//!
//! Given an arbitrary sparse matrix whose bipartite graph (rows ⊔ cols,
//! an edge per non-zero) separates into independent sub-graphs, recover the
//! row/column permutations that expose the block-diagonal structure. The
//! paper uses the 4×4 example of Fig. 1(a): union-find over non-zeros groups
//! rows and columns into components; sorting rows/cols by component yields
//! the permutations of Fig. 1(c).
//!
//! This module is the analysis/verification counterpart of mask *generation*:
//! [`decompose`] applied to `M ∘ W` (for any MPD mask `M`) recovers a block
//! structure equivalent to the mask's own layout, which the round-trip tests
//! assert.

use crate::mask::blockdiag::{grouping_permutation, BlockDiagLayout, Span};
use crate::mask::perm::Permutation;

/// Disjoint-set union with path halving + union by size.
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        Self { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp; // path halving
            x = gp as usize;
        }
        x
    }

    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
        true
    }

    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }
}

/// Result of a sub-graph-separation analysis.
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// Row permutation sorting rows into component order.
    pub p_row: Permutation,
    /// Column permutation sorting columns into component order.
    pub p_col: Permutation,
    /// Recovered (possibly ragged) block layout after applying the perms.
    pub layout: BlockDiagLayout,
    /// Number of independent sub-graphs found (isolated rows/cols are folded
    /// into trailing singleton blocks).
    pub ncomponents: usize,
}

/// Analyze the sparsity pattern of a dense `rows × cols` matrix and, if its
/// bipartite graph separates, produce permutations exposing the blocks.
///
/// Always succeeds; a fully-connected matrix simply yields one block (no
/// compression win). Zero rows/columns are appended to the final block so
/// the result is still a complete partition.
pub fn decompose(data: &[f32], rows: usize, cols: usize) -> Decomposition {
    assert_eq!(data.len(), rows * cols);
    // union-find over rows (ids 0..rows) and cols (ids rows..rows+cols)
    let mut uf = UnionFind::new(rows + cols);
    for r in 0..rows {
        for c in 0..cols {
            if data[r * cols + c] != 0.0 {
                uf.union(r, rows + c);
            }
        }
    }
    // canonical component ids in order of first appearance over rows, cols
    let mut comp_of_root: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    let mut row_comp = vec![usize::MAX; rows];
    let mut col_comp = vec![usize::MAX; cols];
    let mut empty_rows = Vec::new();
    let mut empty_cols = Vec::new();
    for r in 0..rows {
        let has_nz = (0..cols).any(|c| data[r * cols + c] != 0.0);
        if !has_nz {
            empty_rows.push(r);
            continue;
        }
        let root = uf.find(r);
        let next = comp_of_root.len();
        row_comp[r] = *comp_of_root.entry(root).or_insert(next);
    }
    for c in 0..cols {
        let has_nz = (0..rows).any(|r| data[r * cols + c] != 0.0);
        if !has_nz {
            empty_cols.push(c);
            continue;
        }
        let root = uf.find(rows + c);
        let next = comp_of_root.len();
        col_comp[c] = *comp_of_root.entry(root).or_insert(next);
    }
    let ncomponents = comp_of_root.len().max(1);

    // Fold empty rows/cols into the last component so partitions stay complete.
    let last = ncomponents - 1;
    for &r in &empty_rows {
        row_comp[r] = last;
    }
    for &c in &empty_cols {
        col_comp[c] = last;
    }

    let p_row = grouping_permutation(&row_comp, ncomponents);
    let p_col = grouping_permutation(&col_comp, ncomponents);

    // Component sizes → ragged spans.
    let mut row_counts = vec![0usize; ncomponents];
    for &b in &row_comp {
        row_counts[b] += 1;
    }
    let mut col_counts = vec![0usize; ncomponents];
    for &b in &col_comp {
        col_counts[b] += 1;
    }
    let spans = |counts: &[usize]| {
        let mut out = Vec::with_capacity(counts.len());
        let mut start = 0;
        for &len in counts {
            out.push(Span { start, len });
            start += len;
        }
        out
    };
    let layout = BlockDiagLayout::from_spans(rows, cols, spans(&row_counts), spans(&col_counts));

    Decomposition { p_row, p_col, layout, ncomponents }
}

/// Apply a decomposition: permute `data` so the blocks sit on the diagonal.
pub fn apply_decomposition(data: &[f32], rows: usize, cols: usize, d: &Decomposition) -> Vec<f32> {
    let tmp = d.p_row.apply_rows(data, rows, cols);
    d.p_col.apply_cols(&tmp, rows, cols)
}

/// Verify the central claim: after applying the recovered permutations, all
/// non-zero mass lies inside the recovered diagonal blocks.
pub fn verify_decomposition(data: &[f32], rows: usize, cols: usize, d: &Decomposition) -> bool {
    let blocked = apply_decomposition(data, rows, cols, d);
    crate::mask::blockdiag::off_block_mass(&blocked, &d.layout) == 0.0
}

/// The paper's Fig. 1(a) worked example: a 4×4 irregular sparse matrix whose
/// graph splits into two 2×2 sub-graphs. Non-zeros at
/// (x1,y2), (x1,y4), (x3,y2), (x3,y4) and (x2,y1), (x2,y3), (x4,y1), (x4,y3).
pub fn fig1_example() -> (Vec<f32>, usize, usize) {
    #[rustfmt::skip]
    let m = vec![
        0.0, 1.0, 0.0, 1.0, // x1 — connects y2, y4
        1.0, 0.0, 1.0, 0.0, // x2 — connects y1, y3
        0.0, 1.0, 0.0, 1.0, // x3 — connects y2, y4
        1.0, 0.0, 1.0, 0.0, // x4 — connects y1, y3
    ];
    (m, 4, 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::mask::MpdMask;
    use crate::mask::prng::Xoshiro256pp;

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(5);
        assert!(uf.union(0, 1));
        assert!(uf.union(3, 4));
        assert!(!uf.union(1, 0));
        assert!(uf.same(0, 1));
        assert!(!uf.same(0, 3));
        uf.union(1, 3);
        assert!(uf.same(0, 4));
        assert!(!uf.same(2, 0));
    }

    #[test]
    fn fig1_example_decomposes_into_two_blocks() {
        let (m, r, c) = fig1_example();
        let d = decompose(&m, r, c);
        assert_eq!(d.ncomponents, 2);
        assert!(verify_decomposition(&m, r, c, &d));
        // Each block is 2×2 (paper Fig 1c)
        assert_eq!(d.layout.row_spans.iter().map(|s| s.len).collect::<Vec<_>>(), vec![2, 2]);
        assert_eq!(d.layout.col_spans.iter().map(|s| s.len).collect::<Vec<_>>(), vec![2, 2]);
    }

    #[test]
    fn fully_dense_matrix_is_one_block() {
        let data = vec![1.0f32; 12];
        let d = decompose(&data, 3, 4);
        assert_eq!(d.ncomponents, 1);
        assert!(verify_decomposition(&data, 3, 4, &d));
    }

    #[test]
    fn zero_matrix_is_handled() {
        let data = vec![0.0f32; 12];
        let d = decompose(&data, 4, 3);
        assert!(verify_decomposition(&data, 4, 3, &d));
    }

    #[test]
    fn recovers_planted_mpd_structure() {
        // decompose(M ∘ W) must find ≥ nblocks-separable structure and a
        // verifying permutation pair, for any MPD mask.
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        for (rows, cols, k) in [(30, 20, 5), (300, 100, 10), (64, 64, 8)] {
            let mask = MpdMask::generate(rows, cols, k, &mut rng);
            let w: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.11).sin() + 2.0).collect();
            let masked = mask.apply(&w);
            let d = decompose(&masked, rows, cols);
            assert!(verify_decomposition(&masked, rows, cols, &d), "{rows}x{cols} k={k}");
            assert_eq!(d.ncomponents, k, "expected {k} components, got {}", d.ncomponents);
        }
    }

    #[test]
    fn isolated_rows_fold_into_last_block() {
        // 5×4 with an all-zero row 2
        #[rustfmt::skip]
        let m = vec![
            1.0, 0.0, 0.0, 0.0,
            0.0, 1.0, 0.0, 0.0,
            0.0, 0.0, 0.0, 0.0,
            0.0, 0.0, 1.0, 0.0,
            0.0, 0.0, 0.0, 1.0,
        ];
        let d = decompose(&m, 5, 4);
        assert!(verify_decomposition(&m, 5, 4, &d));
        let total_rows: usize = d.layout.row_spans.iter().map(|s| s.len).sum();
        assert_eq!(total_rows, 5);
    }
}
