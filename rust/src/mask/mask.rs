//! The MPD mask itself: `M = P_row · B · P_col` (paper §2, Algorithm 1).
//!
//! An [`MpdMask`] bundles the block-diagonal layout `B` and the two random
//! permutations; the dense binary mask is materialized on demand. Keeping the
//! factored form around (rather than just the 0/1 matrix) is what enables the
//! inference-time re-blocking of eq. 2 — `W* = P_rowᵀ · W̄ · P_colᵀ` — and the
//! consecutive-layer permutation fusion the paper mentions at the end of §2.

use crate::mask::blockdiag::{pack_blocks, partition, BlockDiagLayout, Span};
use crate::mask::perm::Permutation;
use crate::mask::prng::Xoshiro256pp;

/// A binary mask for one FC layer, in factored form.
#[derive(Clone, Debug)]
pub struct MpdMask {
    /// `rows × cols` of the weight matrix this mask applies to.
    pub layout: BlockDiagLayout,
    /// Row permutation `P_row` (applied to rows of `B`).
    pub p_row: Permutation,
    /// Column permutation `P_col` (applied to columns of `B`).
    pub p_col: Permutation,
}

impl MpdMask {
    /// Generate a mask for a `rows × cols` weight matrix with `nblocks`
    /// diagonal blocks (density `≈ 1/nblocks`), using random permutations.
    pub fn generate(rows: usize, cols: usize, nblocks: usize, rng: &mut Xoshiro256pp) -> Self {
        Self {
            layout: BlockDiagLayout::new(rows, cols, nblocks),
            p_row: Permutation::random(rows, rng),
            p_col: Permutation::random(cols, rng),
        }
    }

    /// The paper's §3.1 ablation: a *non-permuted* block-diagonal mask
    /// (`P_row = P_col = I`). Fig. 4(a) shows this collapses accuracy
    /// (80.2% vs >97% on LeNet-300-100) because identity blocks sever
    /// information flow between neuron groups.
    pub fn non_permuted(rows: usize, cols: usize, nblocks: usize) -> Self {
        Self {
            layout: BlockDiagLayout::new(rows, cols, nblocks),
            p_row: Permutation::identity(rows),
            p_col: Permutation::identity(cols),
        }
    }

    /// Compose per-group MPD masks into one mask over the full filter matrix
    /// of a `groups`-grouped conv. Group `g` owns the contiguous row span
    /// `[g·rows/groups, (g+1)·rows/groups)` and column span
    /// `[g·cols/groups, (g+1)·cols/groups)` (patch columns of a group's
    /// input channels are contiguous — see `linalg::im2col`); within its
    /// spans each group gets an independent `nblocks`-block MPD mask, so the
    /// composed mask is a `groups·nblocks`-block layout whose permutations
    /// never cross a group boundary. Masked density is `1/nblocks` of the
    /// grouped conv's *live* weights (`1/(groups·nblocks)` of the full
    /// filter matrix).
    pub fn grouped(
        rows: usize,
        cols: usize,
        groups: usize,
        nblocks: usize,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        Self::grouped_with(rows, cols, groups, nblocks, |n| Permutation::random(n, rng))
    }

    /// [`Self::grouped`] with identity permutations — the lowering structure
    /// of a *dense* grouped conv (`nblocks = 1` per group ⇒ `groups` blocks)
    /// and the §3.1-ablation variant of a masked one.
    pub fn grouped_non_permuted(rows: usize, cols: usize, groups: usize, nblocks: usize) -> Self {
        Self::grouped_with(rows, cols, groups, nblocks, Permutation::identity)
    }

    fn grouped_with(
        rows: usize,
        cols: usize,
        groups: usize,
        nblocks: usize,
        mut perm: impl FnMut(usize) -> Permutation,
    ) -> Self {
        assert!(groups >= 1, "need at least one group");
        assert!(
            rows % groups == 0 && cols % groups == 0,
            "groups {groups} must divide filter matrix {rows}×{cols}"
        );
        let (rg, cg) = (rows / groups, cols / groups);
        let mut row_spans = Vec::with_capacity(groups * nblocks);
        let mut col_spans = Vec::with_capacity(groups * nblocks);
        let mut row_map = vec![0u32; rows];
        let mut col_map = vec![0u32; cols];
        for g in 0..groups {
            for s in partition(rg, nblocks) {
                row_spans.push(Span { start: g * rg + s.start, len: s.len });
            }
            for s in partition(cg, nblocks) {
                col_spans.push(Span { start: g * cg + s.start, len: s.len });
            }
            let pr = perm(rg);
            let pc = perm(cg);
            for i in 0..rg {
                row_map[g * rg + i] = (g * rg + pr.dest(i)) as u32;
            }
            for i in 0..cg {
                col_map[g * cg + i] = (g * cg + pc.dest(i)) as u32;
            }
        }
        Self {
            layout: BlockDiagLayout::from_spans(rows, cols, row_spans, col_spans),
            p_row: Permutation::from_map(row_map).expect("per-group perms compose to a bijection"),
            p_col: Permutation::from_map(col_map).expect("per-group perms compose to a bijection"),
        }
    }

    pub fn rows(&self) -> usize {
        self.layout.rows
    }

    pub fn cols(&self) -> usize {
        self.layout.cols
    }

    pub fn nblocks(&self) -> usize {
        self.layout.nblocks()
    }

    /// Number of surviving weights.
    pub fn nnz(&self) -> usize {
        self.layout.nnz()
    }

    pub fn density(&self) -> f64 {
        self.layout.density()
    }

    /// Materialize the dense 0/1 mask `M = P_row B P_col`, row-major.
    ///
    /// Mask entry `(r, c)` is 1 iff the un-permuted coordinate
    /// `(p_row⁻¹(r), p_col⁻¹(c))` lies on a diagonal block of `B`.
    pub fn to_dense(&self) -> Vec<f32> {
        let rows = self.rows();
        let cols = self.cols();
        let inv_r = self.p_row.inverse();
        let inv_c = self.p_col.inverse();
        let mut m = vec![0.0f32; rows * cols];
        // iterate over B's blocks and scatter — O(nnz), not O(rows·cols)
        for (b, rs) in self.layout.row_spans.iter().enumerate() {
            let cs = self.layout.col_spans[b];
            for br in rs.start..rs.end() {
                let r = self.p_row.dest(br);
                let row = &mut m[r * cols..(r + 1) * cols];
                for bc in cs.start..cs.end() {
                    row[self.p_col.dest(bc)] = 1.0;
                }
            }
        }
        debug_assert_eq!(inv_r.len(), rows);
        debug_assert_eq!(inv_c.len(), cols);
        m
    }

    /// Apply the mask element-wise to a weight matrix: `W̄ = M ∘ W` (eq. 1).
    pub fn apply(&self, w: &[f32]) -> Vec<f32> {
        let mut out = w.to_vec();
        self.apply_inplace(&mut out);
        out
    }

    /// In-place `W ← M ∘ W` — the per-training-step operation of Algorithm 1
    /// line 14. O(rows·cols) zeroing via block iteration: zero everything,
    /// then restore surviving entries.
    pub fn apply_inplace(&self, w: &mut [f32]) {
        assert_eq!(w.len(), self.rows() * self.cols());
        let cols = self.cols();
        // Collect surviving values first (O(nnz)), then zero + scatter.
        let mut kept: Vec<(usize, f32)> = Vec::with_capacity(self.nnz());
        for (b, rs) in self.layout.row_spans.iter().enumerate() {
            let cs = self.layout.col_spans[b];
            for br in rs.start..rs.end() {
                let r = self.p_row.dest(br);
                for bc in cs.start..cs.end() {
                    let c = self.p_col.dest(bc);
                    kept.push((r * cols + c, w[r * cols + c]));
                }
            }
        }
        w.iter_mut().for_each(|v| *v = 0.0);
        for (idx, v) in kept {
            w[idx] = v;
        }
    }

    /// Inference-time re-blocking (eq. 2): `W* = P_rowᵀ · W̄ · P_colᵀ`.
    /// If `W̄ = M ∘ W`, the result is exactly block-diagonal under `layout`.
    pub fn unpermute(&self, w_masked: &[f32]) -> Vec<f32> {
        // P_rowᵀ = P_row⁻¹ applied to rows; P_colᵀ = P_col⁻¹ applied to cols.
        let rows = self.rows();
        let cols = self.cols();
        let r = self.p_row.inverse().apply_rows(w_masked, rows, cols);
        self.p_col.inverse().apply_cols(&r, rows, cols)
    }

    /// Full packing: mask → unpermute → extract dense blocks. Returns the
    /// packed block storage (`nnz` floats) ready for the block-diagonal GEMM.
    pub fn pack(&self, w_masked: &[f32]) -> Vec<f32> {
        let star = self.unpermute(w_masked);
        pack_blocks(&star, &self.layout)
    }
}

/// Element-wise sum of many dense masks — reproduces Fig. 4(b): the sum of
/// 100 random masks is near-uniform with mean `n_masks × density`.
pub fn sum_masks(masks: &[MpdMask]) -> Vec<f32> {
    assert!(!masks.is_empty());
    let rows = masks[0].rows();
    let cols = masks[0].cols();
    let mut sum = vec![0.0f32; rows * cols];
    for m in masks {
        assert_eq!(m.rows(), rows);
        assert_eq!(m.cols(), cols);
        for (s, v) in sum.iter_mut().zip(m.to_dense()) {
            *s += v;
        }
    }
    sum
}

/// Summary statistics of a mask-sum matrix (Fig. 4(b) commentary: "the sum on
/// average reached 10, confirming the high spread of non-zero mask values").
#[derive(Clone, Copy, Debug)]
pub struct MaskSumStats {
    pub mean: f64,
    pub min: f32,
    pub max: f32,
    pub variance: f64,
    /// Fraction of matrix positions never covered by any mask.
    pub never_covered: f64,
}

pub fn mask_sum_stats(sum: &[f32]) -> MaskSumStats {
    let n = sum.len() as f64;
    let mean = sum.iter().map(|&v| v as f64).sum::<f64>() / n;
    let variance = sum.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let min = sum.iter().cloned().fold(f32::INFINITY, f32::min);
    let max = sum.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let never_covered = sum.iter().filter(|&&v| v == 0.0).count() as f64 / n;
    MaskSumStats { mean, min, max, variance, never_covered }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::blockdiag::off_block_mass;

    fn rng(seed: u64) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(seed)
    }

    #[test]
    fn dense_mask_has_layout_nnz() {
        let mut r = rng(1);
        let m = MpdMask::generate(30, 20, 5, &mut r);
        let d = m.to_dense();
        assert_eq!(d.iter().filter(|&&v| v == 1.0).count(), m.nnz());
        assert!(d.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn non_permuted_mask_is_block_diagonal() {
        let m = MpdMask::non_permuted(12, 9, 3);
        let d = m.to_dense();
        assert_eq!(d, m.layout.to_dense());
    }

    #[test]
    fn apply_matches_elementwise_product() {
        let mut r = rng(2);
        let m = MpdMask::generate(17, 13, 4, &mut r);
        let w: Vec<f32> = (0..17 * 13).map(|i| (i as f32).sin()).collect();
        let masked = m.apply(&w);
        let dense = m.to_dense();
        for i in 0..w.len() {
            assert_eq!(masked[i], dense[i] * w[i]);
        }
    }

    #[test]
    fn unpermute_recovers_block_diagonal_exactly() {
        // The core eq.-2 invariant: mask → unpermute ⇒ zero off-block mass.
        let mut r = rng(3);
        for (rows, cols, k) in [(300, 100, 10), (20, 30, 4), (7, 7, 7)] {
            let m = MpdMask::generate(rows, cols, k, &mut r);
            let w: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.37).cos()).collect();
            let masked = m.apply(&w);
            let star = m.unpermute(&masked);
            assert_eq!(off_block_mass(&star, &m.layout), 0.0, "{rows}x{cols} k={k}");
        }
    }

    #[test]
    fn unpermute_is_inverse_of_permute() {
        // Building M from B by permutations and unpermuting M∘W must equal
        // B ∘ (P_rowᵀ W P_colᵀ)  (paper's W̄ ~ P_rowᵀ W P_colᵀ ∘ B relation)
        let mut r = rng(4);
        let m = MpdMask::generate(15, 10, 5, &mut r);
        let w: Vec<f32> = (0..150).map(|i| i as f32 + 1.0).collect();
        let star = m.unpermute(&m.apply(&w));
        let wp = m.p_row.inverse().apply_rows(&w, 15, 10);
        let wp = m.p_col.inverse().apply_cols(&wp, 15, 10);
        let b = m.layout.to_dense();
        let expect: Vec<f32> = wp.iter().zip(&b).map(|(x, y)| x * y).collect();
        assert_eq!(star, expect);
    }

    #[test]
    fn pack_keeps_all_surviving_weights() {
        let mut r = rng(5);
        let m = MpdMask::generate(24, 18, 6, &mut r);
        let w: Vec<f32> = (0..24 * 18).map(|i| i as f32 + 1.0).collect(); // all nonzero
        let masked = m.apply(&w);
        let packed = m.pack(&masked);
        assert_eq!(packed.len(), m.nnz());
        // every packed value is one of the surviving masked values
        let mut survivors: Vec<f32> = masked.iter().cloned().filter(|&v| v != 0.0).collect();
        let mut p = packed.clone();
        survivors.sort_by(f32::total_cmp);
        p.sort_by(f32::total_cmp);
        assert_eq!(p, survivors);
    }

    #[test]
    fn apply_inplace_idempotent() {
        let mut r = rng(6);
        let m = MpdMask::generate(9, 11, 3, &mut r);
        let mut w: Vec<f32> = (0..99).map(|i| i as f32 - 50.0).collect();
        m.apply_inplace(&mut w);
        let once = w.clone();
        m.apply_inplace(&mut w);
        assert_eq!(w, once);
    }

    #[test]
    fn sum_of_masks_statistics() {
        // Fig 4(b): 100 masks, 300×100, 10% density ⇒ mean sum = 10.
        let mut r = rng(7);
        let masks: Vec<MpdMask> = (0..100).map(|_| MpdMask::generate(300, 100, 10, &mut r)).collect();
        let sum = sum_masks(&masks);
        let stats = mask_sum_stats(&sum);
        assert!((stats.mean - 10.0).abs() < 1e-9, "mean {}", stats.mean);
        // near-uniform spread: essentially no never-covered cells
        assert!(stats.never_covered < 0.001, "never covered {}", stats.never_covered);
        assert!(stats.max < 30.0, "suspicious hot spot {}", stats.max);
    }

    #[test]
    fn grouped_mask_confines_to_groups() {
        let mut r = rng(8);
        let m = MpdMask::grouped(8, 12, 2, 2, &mut r);
        assert_eq!(m.nblocks(), 4);
        let d = m.to_dense();
        // no surviving entry crosses a group boundary
        for row in 0..8 {
            for col in 0..12 {
                if d[row * 12 + col] == 1.0 {
                    assert_eq!(row / 4, col / 6, "mask crosses group boundary at ({row},{col})");
                }
            }
        }
        // density 1/(groups·nblocks) of the full matrix
        assert_eq!(m.nnz(), 8 * 12 / (2 * 2));
        // the eq.-2 invariant survives composition
        let w: Vec<f32> = (0..96).map(|i| (i as f32 * 0.7).sin()).collect();
        let star = m.unpermute(&m.apply(&w));
        assert_eq!(off_block_mass(&star, &m.layout), 0.0);
        // groups = 1 degenerates to the plain generator
        let mut r1 = rng(9);
        let mut r2 = rng(9);
        let a = MpdMask::grouped(10, 15, 1, 5, &mut r1);
        let b = MpdMask::generate(10, 15, 5, &mut r2);
        assert_eq!(a.to_dense(), b.to_dense());
    }

    #[test]
    fn grouped_non_permuted_single_block_is_group_structure() {
        // nblocks = 1 per group ⇒ the block-diagonal structure of a dense
        // grouped conv's filter matrix.
        let m = MpdMask::grouped_non_permuted(4, 6, 2, 1);
        assert!(m.p_row.is_identity() && m.p_col.is_identity());
        let d = m.to_dense();
        for row in 0..4 {
            for col in 0..6 {
                assert_eq!(d[row * 6 + col] == 1.0, row / 2 == col / 3, "({row},{col})");
            }
        }
    }

    #[test]
    fn distinct_seeds_give_distinct_masks() {
        let mut r1 = rng(100);
        let mut r2 = rng(200);
        let a = MpdMask::generate(50, 40, 5, &mut r1).to_dense();
        let b = MpdMask::generate(50, 40, 5, &mut r2).to_dense();
        assert_ne!(a, b);
    }
}
