//! Deterministic, dependency-free PRNGs for mask generation and synthetic data.
//!
//! MPDCompress is built on *random* permutations (paper §2): every mask is a
//! random row/column shuffle of a block-diagonal matrix. Reproducibility of an
//! experiment therefore hinges on the PRNG, so we pin the algorithms here
//! instead of depending on an external crate whose stream could change:
//!
//! * [`SplitMix64`] — Steele/Lea/Burleigh seeding generator; used to expand a
//!   single `u64` seed into the 256-bit state of the main generator.
//! * [`Xoshiro256pp`] — Blackman/Vigna xoshiro256++ 1.0, the workhorse.
//!
//! Both match the published reference implementations bit-for-bit (see the
//! vector tests at the bottom).

/// SplitMix64: a tiny 64-bit generator used to seed [`Xoshiro256pp`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits (reference: Vigna, `splitmix64.c`).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 per the authors' recommendation, rejecting the
    /// (probability ~2^-256) all-zero state.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        loop {
            for w in s.iter_mut() {
                *w = sm.next_u64();
            }
            if s.iter().any(|&w| w != 0) {
                return Self { s };
            }
        }
    }

    /// Construct from raw state (must not be all zero).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&w| w != 0), "xoshiro state must be nonzero");
        Self { s }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    /// Next 64 random bits (reference: Blackman & Vigna, `xoshiro256plusplus.c`).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Next 32 random bits (upper half — the stronger bits of ++ scramblers).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Rejection sampling to kill modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of randomness.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of randomness.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Marsaglia polar method.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-layer / per-worker
    /// streams) by hashing the parent stream with a stream id.
    pub fn fork(&mut self, stream: u64) -> Self {
        let a = self.next_u64();
        let mut sm = SplitMix64::new(a ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = sm.next_u64();
        }
        if s.iter().all(|&w| w == 0) {
            s[0] = 1;
        }
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vector() {
        // xoshiro256++ with state {1,2,3,4}: first outputs from the reference C
        // implementation (Blackman & Vigna, public domain).
        let mut g = Xoshiro256pp::from_state([1, 2, 3, 4]);
        let expect = [
            41943041u64,
            58720359,
            3588806011781223,
            3591011842654386,
            9228616714210784205,
        ];
        for e in expect {
            assert_eq!(g.next_u64(), e);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn next_below_in_range_and_unbiased_ish() {
        let mut g = Xoshiro256pp::seed_from_u64(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            counts[v] += 1;
        }
        for &c in &counts {
            // each bucket expected 1000; allow wide tolerance
            assert!((700..1300).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut g = Xoshiro256pp::seed_from_u64(9);
        for _ in 0..10_000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::seed_from_u64(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let v = g.next_normal();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Xoshiro256pp::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left slice unchanged");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut g = Xoshiro256pp::seed_from_u64(1);
        let mut a = g.fork(0);
        let mut b = g.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
