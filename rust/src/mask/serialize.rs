//! Mask serialization: persist [`MpdMask`]s in *factored* form (layout +
//! permutations), not as dense 0/1 matrices — 2(rows+cols) u32s instead of
//! rows×cols floats, and the factored form is what inference-time packing
//! needs anyway. Reuses the MPDC checkpoint container (`nn::checkpoint`), so
//! masks inherit its CRC integrity check and atomic-rename publishing.
//!
//! Encoding: per mask `i`, three tensors
//!   `mask{i}.dims`  = [rows, cols, nblocks]           (f32-encoded u32s)
//!   `mask{i}.p_row` = forward map of P_row            (len rows)
//!   `mask{i}.p_col` = forward map of P_col            (len cols)
//! Values are exact: u32 indices ≤ 2^24 round-trip through f32 losslessly,
//! and layer dims beyond 16.7M rows are rejected at save time.

use crate::mask::blockdiag::BlockDiagLayout;
use crate::mask::mask::MpdMask;
use crate::mask::perm::Permutation;
use crate::nn::checkpoint::{self, CheckpointError, NamedTensor};
use std::path::Path;

const F32_EXACT_MAX: usize = 1 << 24;

/// Save a set of masks to `path`.
pub fn save_masks(path: &Path, masks: &[MpdMask]) -> Result<(), CheckpointError> {
    let mut tensors = Vec::with_capacity(masks.len() * 3);
    for (i, m) in masks.iter().enumerate() {
        assert!(
            m.rows() < F32_EXACT_MAX && m.cols() < F32_EXACT_MAX,
            "mask dims exceed exact-f32 range"
        );
        tensors.push(NamedTensor::f32(
            format!("mask{i}.dims"),
            vec![3],
            vec![m.rows() as f32, m.cols() as f32, m.nblocks() as f32],
        ));
        tensors.push(NamedTensor::f32(
            format!("mask{i}.p_row"),
            vec![m.rows()],
            m.p_row.as_slice().iter().map(|&v| v as f32).collect(),
        ));
        tensors.push(NamedTensor::f32(
            format!("mask{i}.p_col"),
            vec![m.cols()],
            m.p_col.as_slice().iter().map(|&v| v as f32).collect(),
        ));
    }
    checkpoint::save(path, &tensors)
}

/// Load masks saved by [`save_masks`].
pub fn load_masks(path: &Path) -> Result<Vec<MpdMask>, String> {
    let tensors = checkpoint::load(path).map_err(|e| e.to_string())?;
    if tensors.len() % 3 != 0 {
        return Err(format!("mask file has {} tensors (expected multiple of 3)", tensors.len()));
    }
    let mut masks = Vec::with_capacity(tensors.len() / 3);
    for (i, chunk) in tensors.chunks(3).enumerate() {
        let [dims, p_row, p_col] = chunk else {
            return Err("bad chunk".into());
        };
        let dims_v = dims.as_f32().ok_or_else(|| format!("mask {i}: dims tensor is not f32"))?;
        if dims.name != format!("mask{i}.dims") || dims_v.len() != 3 {
            return Err(format!("unexpected tensor {} at mask {i}", dims.name));
        }
        let rows = dims_v[0] as usize;
        let cols = dims_v[1] as usize;
        let k = dims_v[2] as usize;
        let p_row_v = p_row.as_f32().ok_or_else(|| format!("mask {i}: p_row tensor is not f32"))?;
        let p_col_v = p_col.as_f32().ok_or_else(|| format!("mask {i}: p_col tensor is not f32"))?;
        if p_row_v.len() != rows || p_col_v.len() != cols {
            return Err(format!("mask {i}: permutation length mismatch"));
        }
        let to_map = |data: &[f32]| -> Result<Permutation, String> {
            Permutation::from_map(data.iter().map(|&v| v as u32).collect())
        };
        masks.push(MpdMask {
            layout: BlockDiagLayout::new(rows, cols, k),
            p_row: to_map(p_row_v).map_err(|e| format!("mask {i} p_row: {e}"))?,
            p_col: to_map(p_col_v).map_err(|e| format!("mask {i} p_col: {e}"))?,
        });
    }
    Ok(masks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::prng::Xoshiro256pp;

    #[test]
    fn roundtrip_preserves_dense_mask() {
        let dir = std::env::temp_dir().join(format!("mpdc_maskser_{}", std::process::id()));
        let path = dir.join("masks.mpdc");
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let masks = vec![
            MpdMask::generate(300, 784, 10, &mut rng),
            MpdMask::generate(100, 300, 10, &mut rng),
            MpdMask::non_permuted(16, 8, 4),
        ];
        save_masks(&path, &masks).unwrap();
        let back = load_masks(&path).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in masks.iter().zip(&back) {
            assert_eq!(a.to_dense(), b.to_dense());
            assert_eq!(a.layout, b.layout);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_permutation() {
        // hand-build a file with a non-bijective p_row
        let dir = std::env::temp_dir().join(format!("mpdc_maskser2_{}", std::process::id()));
        let path = dir.join("bad.mpdc");
        let tensors = vec![
            NamedTensor::f32("mask0.dims", vec![3], vec![2.0, 2.0, 1.0]),
            NamedTensor::f32("mask0.p_row", vec![2], vec![0.0, 0.0]),
            NamedTensor::f32("mask0.p_col", vec![2], vec![0.0, 1.0]),
        ];
        checkpoint::save(&path, &tensors).unwrap();
        assert!(load_masks(&path).unwrap_err().contains("p_row"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
