//! Block-diagonal layouts and binary block-diagonal matrices — the "B" in
//! `M = P_row · B · P_col` (paper §2, Fig. 1(e)).
//!
//! For a `rows × cols` FC weight matrix compressed `k×` (sparsity `1/k`), the
//! paper uses a block-diagonal binary matrix with `k` blocks along the main
//! diagonal. When `rows` or `cols` is not divisible by `k` the blocks are
//! *ragged*: we distribute the remainder one unit at a time over the leading
//! blocks, exactly preserving total density accounting. LeNet-300-100's
//! 784×300 layer at 10 blocks, for example, gets row blocks of 79/78 and
//! column blocks of 30.

use crate::mask::perm::Permutation;

/// Half-open span `[start, start+len)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub len: usize,
}

impl Span {
    pub fn end(&self) -> usize {
        self.start + self.len
    }

    pub fn contains(&self, i: usize) -> bool {
        i >= self.start && i < self.end()
    }
}

/// Partition `n` indices into `k` contiguous spans, remainder spread over the
/// leading spans (sizes differ by at most one).
pub fn partition(n: usize, k: usize) -> Vec<Span> {
    assert!(k > 0, "need at least one block");
    assert!(n >= k, "cannot split {n} indices into {k} non-empty blocks");
    let base = n / k;
    let rem = n % k;
    let mut spans = Vec::with_capacity(k);
    let mut start = 0;
    for b in 0..k {
        let len = base + usize::from(b < rem);
        spans.push(Span { start, len });
        start += len;
    }
    debug_assert_eq!(start, n);
    spans
}

/// The block structure of a block-diagonal `rows × cols` matrix with
/// `nblocks` diagonal blocks. Block `b` occupies `row_spans[b] × col_spans[b]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockDiagLayout {
    pub rows: usize,
    pub cols: usize,
    pub row_spans: Vec<Span>,
    pub col_spans: Vec<Span>,
}

impl BlockDiagLayout {
    pub fn new(rows: usize, cols: usize, nblocks: usize) -> Self {
        Self {
            rows,
            cols,
            row_spans: partition(rows, nblocks),
            col_spans: partition(cols, nblocks),
        }
    }

    /// Construct from explicit spans (used by `decompose` when recovering a
    /// planted structure whose blocks may be irregular).
    pub fn from_spans(rows: usize, cols: usize, row_spans: Vec<Span>, col_spans: Vec<Span>) -> Self {
        assert_eq!(row_spans.len(), col_spans.len());
        debug_assert_eq!(row_spans.iter().map(|s| s.len).sum::<usize>(), rows);
        debug_assert_eq!(col_spans.iter().map(|s| s.len).sum::<usize>(), cols);
        Self { rows, cols, row_spans, col_spans }
    }

    pub fn nblocks(&self) -> usize {
        self.row_spans.len()
    }

    /// Which block a row belongs to.
    pub fn row_block(&self, r: usize) -> usize {
        // spans are contiguous and sorted → binary search on start
        match self.row_spans.binary_search_by(|s| {
            if s.contains(r) {
                std::cmp::Ordering::Equal
            } else if s.start > r {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Less
            }
        }) {
            Ok(b) => b,
            Err(_) => panic!("row {r} out of range"),
        }
    }

    /// Which block a column belongs to.
    pub fn col_block(&self, c: usize) -> usize {
        match self.col_spans.binary_search_by(|s| {
            if s.contains(c) {
                std::cmp::Ordering::Equal
            } else if s.start > c {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Less
            }
        }) {
            Ok(b) => b,
            Err(_) => panic!("col {c} out of range"),
        }
    }

    /// Number of non-zeros of the binary block-diagonal matrix: Σ rᵦ·cᵦ.
    pub fn nnz(&self) -> usize {
        self.row_spans
            .iter()
            .zip(&self.col_spans)
            .map(|(r, c)| r.len * c.len)
            .sum()
    }

    /// Density = nnz / (rows·cols). For `k` even blocks this is `1/k` — the
    /// paper's "sparsity level" hyper-parameter (10% sparsity ⇔ 10 blocks).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Achieved compression factor = dense params / kept params.
    pub fn compression(&self) -> f64 {
        (self.rows * self.cols) as f64 / self.nnz() as f64
    }

    /// Materialize the dense binary block-diagonal matrix `B` (row-major).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut b = vec![0.0f32; self.rows * self.cols];
        for (rs, cs) in self.row_spans.iter().zip(&self.col_spans) {
            for r in rs.start..rs.end() {
                for c in cs.start..cs.end() {
                    b[r * self.cols + c] = 1.0;
                }
            }
        }
        b
    }

    /// True iff `(r, c)` lies inside a diagonal block.
    pub fn is_on_block(&self, r: usize, c: usize) -> bool {
        self.row_block(r) == self.col_block(c)
    }

    /// The number of blocks needed for a target density (paper: sparsity s ⇒
    /// k = round(1/s) blocks; e.g. 12.5% ⇒ 8 blocks ⇒ 8× compression).
    pub fn blocks_for_density(density: f64) -> usize {
        assert!(density > 0.0 && density <= 1.0, "density must be in (0, 1]");
        (1.0 / density).round().max(1.0) as usize
    }
}

/// Extract the dense sub-blocks of a (already block-diagonal) matrix
/// according to `layout`, concatenated in block order. This is the packed
/// storage the inference engine actually multiplies with — `nnz` floats
/// instead of `rows*cols`.
pub fn pack_blocks(data: &[f32], layout: &BlockDiagLayout) -> Vec<f32> {
    assert_eq!(data.len(), layout.rows * layout.cols);
    let mut packed = Vec::with_capacity(layout.nnz());
    for (rs, cs) in layout.row_spans.iter().zip(&layout.col_spans) {
        for r in rs.start..rs.end() {
            packed.extend_from_slice(&data[r * layout.cols + cs.start..r * layout.cols + cs.end()]);
        }
    }
    packed
}

/// Inverse of [`pack_blocks`]: scatter packed blocks back into a dense
/// (block-diagonal) matrix.
pub fn unpack_blocks(packed: &[f32], layout: &BlockDiagLayout) -> Vec<f32> {
    assert_eq!(packed.len(), layout.nnz());
    let mut dense = vec![0.0f32; layout.rows * layout.cols];
    let mut off = 0;
    for (rs, cs) in layout.row_spans.iter().zip(&layout.col_spans) {
        for r in rs.start..rs.end() {
            dense[r * layout.cols + cs.start..r * layout.cols + cs.end()]
                .copy_from_slice(&packed[off..off + cs.len]);
            off += cs.len;
        }
    }
    dense
}

/// Mass outside the diagonal blocks — used to verify that training with a
/// mask really confined the weights (should be exactly 0 after masking).
pub fn off_block_mass(data: &[f32], layout: &BlockDiagLayout) -> f64 {
    let mut mass = 0.0f64;
    for (b, rs) in layout.row_spans.iter().enumerate() {
        let cs = layout.col_spans[b];
        for r in rs.start..rs.end() {
            for c in 0..layout.cols {
                if !cs.contains(c) {
                    mass += (data[r * layout.cols + c] as f64).abs();
                }
            }
        }
    }
    mass
}

/// Row/column permutations that sort a *permuted* block-diagonal matrix back
/// to block form given the block id of every row/col (helper shared with
/// `decompose`): rows are grouped by block, preserving relative order.
pub fn grouping_permutation(block_of: &[usize], nblocks: usize) -> Permutation {
    let mut counts = vec![0usize; nblocks];
    for &b in block_of {
        assert!(b < nblocks);
        counts[b] += 1;
    }
    let mut starts = vec![0usize; nblocks];
    let mut acc = 0;
    for b in 0..nblocks {
        starts[b] = acc;
        acc += counts[b];
    }
    let mut map = vec![0u32; block_of.len()];
    for (i, &b) in block_of.iter().enumerate() {
        map[i] = starts[b] as u32;
        starts[b] += 1;
    }
    Permutation::from_map(map).expect("grouping produces a bijection")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_even_and_ragged() {
        let p = partition(100, 10);
        assert_eq!(p.len(), 10);
        assert!(p.iter().all(|s| s.len == 10));

        // LeNet 784×300 with 10 blocks: 784 = 4×79 + 6×78
        let p = partition(784, 10);
        assert_eq!(p.iter().map(|s| s.len).sum::<usize>(), 784);
        assert_eq!(p[0].len, 79);
        assert_eq!(p[9].len, 78);
        assert!(p.iter().all(|s| s.len == 78 || s.len == 79));
        // spans are contiguous
        for w in p.windows(2) {
            assert_eq!(w[0].end(), w[1].start);
        }
    }

    #[test]
    #[should_panic]
    fn partition_rejects_too_many_blocks() {
        partition(5, 6);
    }

    #[test]
    fn layout_density_matches_paper_sparsity() {
        // 300×100 at 10 blocks → 10% density, 10× compression (paper Fig 1e)
        let l = BlockDiagLayout::new(300, 100, 10);
        assert_eq!(l.nnz(), 3000);
        assert!((l.density() - 0.1).abs() < 1e-12);
        assert!((l.compression() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn blocks_for_density_inverts() {
        assert_eq!(BlockDiagLayout::blocks_for_density(0.10), 10);
        assert_eq!(BlockDiagLayout::blocks_for_density(0.125), 8);
        assert_eq!(BlockDiagLayout::blocks_for_density(0.0625), 16);
        assert_eq!(BlockDiagLayout::blocks_for_density(0.25), 4);
        assert_eq!(BlockDiagLayout::blocks_for_density(1.0), 1);
    }

    #[test]
    fn to_dense_nnz_and_block_membership() {
        let l = BlockDiagLayout::new(12, 8, 4);
        let d = l.to_dense();
        let nnz = d.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, l.nnz());
        for r in 0..12 {
            for c in 0..8 {
                let expect = l.is_on_block(r, c);
                assert_eq!(d[r * 8 + c] == 1.0, expect, "({r},{c})");
            }
        }
    }

    #[test]
    fn row_col_block_lookup() {
        let l = BlockDiagLayout::new(10, 10, 3); // rows 4,3,3
        assert_eq!(l.row_block(0), 0);
        assert_eq!(l.row_block(3), 0);
        assert_eq!(l.row_block(4), 1);
        assert_eq!(l.row_block(9), 2);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let l = BlockDiagLayout::new(9, 7, 3);
        // fill a block-diagonal matrix with distinct values on blocks
        let mut dense = vec![0.0f32; 63];
        for (b, rs) in l.row_spans.iter().enumerate() {
            let cs = l.col_spans[b];
            for r in rs.start..rs.end() {
                for c in cs.start..cs.end() {
                    dense[r * 7 + c] = (r * 100 + c) as f32;
                }
            }
        }
        let packed = pack_blocks(&dense, &l);
        assert_eq!(packed.len(), l.nnz());
        let back = unpack_blocks(&packed, &l);
        assert_eq!(back, dense);
    }

    #[test]
    fn off_block_mass_detects_leaks() {
        let l = BlockDiagLayout::new(6, 6, 2);
        let mut dense = l.to_dense();
        assert_eq!(off_block_mass(&dense, &l), 0.0);
        dense[0 * 6 + 5] = 2.5; // row 0 is block 0, col 5 is block 1
        assert!((off_block_mass(&dense, &l) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn grouping_permutation_groups() {
        let block_of = vec![1usize, 0, 1, 0, 2];
        let p = grouping_permutation(&block_of, 3);
        // indices of block 0 (1, 3) must land in positions 0..2, etc.
        assert_eq!(p.dest(1), 0);
        assert_eq!(p.dest(3), 1);
        assert_eq!(p.dest(0), 2);
        assert_eq!(p.dest(2), 3);
        assert_eq!(p.dest(4), 4);
    }
}
