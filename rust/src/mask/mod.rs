//! Mask substrate: PRNGs, permutations, block-diagonal layouts, MPD masks,
//! and the Fig.-1 sub-graph-separation decomposition.
pub mod blockdiag;
pub mod decompose;
pub mod mask;
pub mod perm;
pub mod prng;
pub mod serialize;

pub use blockdiag::BlockDiagLayout;
pub use decompose::{decompose, Decomposition};
pub use mask::{mask_sum_stats, sum_masks, MpdMask};
pub use perm::Permutation;
pub use prng::Xoshiro256pp;
