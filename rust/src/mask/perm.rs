//! Permutations — the "P" in MPDCompress's `M = P_row · B · P_col` (paper §2).
//!
//! A [`Permutation`] is stored as a forward map `map[i] = j`, meaning element
//! at source index `i` moves to destination index `j` — equivalently, the
//! permutation matrix `P` with `P[j][i] = 1`, so that for a vector `x`,
//! `(P x)[map[i]] = x[i]`.
//!
//! The paper applies `P_row` to rows and `P_col` to columns of a
//! block-diagonal binary matrix `B` to produce a mask `M`, then at inference
//! time undoes them (`Wᵢ* = P_rowᵀ · W̄ᵢ · P_colᵀ`, eq. 2) to recover the
//! block-diagonal structure. Everything in this file is exercised by the
//! round-trip property tests at the bottom and in `mask::decompose`.

use crate::mask::prng::Xoshiro256pp;

/// A permutation of `n` indices, stored as a forward map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    map: Vec<u32>,
}

impl Permutation {
    /// Identity permutation of size `n`.
    pub fn identity(n: usize) -> Self {
        Self { map: (0..n as u32).collect() }
    }

    /// Uniformly random permutation of size `n` (Fisher–Yates).
    pub fn random(n: usize, rng: &mut Xoshiro256pp) -> Self {
        let mut map: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut map);
        Self { map }
    }

    /// Build from an explicit forward map. Validates it is a bijection.
    pub fn from_map(map: Vec<u32>) -> Result<Self, String> {
        let n = map.len();
        let mut seen = vec![false; n];
        for &j in &map {
            let j = j as usize;
            if j >= n {
                return Err(format!("index {j} out of range for permutation of size {n}"));
            }
            if seen[j] {
                return Err(format!("duplicate destination index {j}"));
            }
            seen[j] = true;
        }
        Ok(Self { map })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn is_identity(&self) -> bool {
        self.map.iter().enumerate().all(|(i, &j)| i as u32 == j)
    }

    /// Forward map: source index `i` → destination index.
    #[inline]
    pub fn dest(&self, i: usize) -> usize {
        self.map[i] as usize
    }

    /// Raw forward map.
    pub fn as_slice(&self) -> &[u32] {
        &self.map
    }

    /// The inverse permutation: `inv.dest(self.dest(i)) == i`.
    pub fn inverse(&self) -> Self {
        let mut inv = vec![0u32; self.map.len()];
        for (i, &j) in self.map.iter().enumerate() {
            inv[j as usize] = i as u32;
        }
        Self { map: inv }
    }

    /// Composition `self ∘ other`: first apply `other`, then `self`.
    pub fn compose(&self, other: &Self) -> Self {
        assert_eq!(self.len(), other.len(), "composing permutations of different sizes");
        let map = (0..self.len()).map(|i| self.map[other.map[i] as usize]).collect();
        Self { map }
    }

    /// Permute a vector: `out[dest(i)] = x[i]`.
    pub fn apply_vec<T: Copy + Default>(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.len());
        let mut out = vec![T::default(); x.len()];
        for (i, &v) in x.iter().enumerate() {
            out[self.map[i] as usize] = v;
        }
        out
    }

    /// Permute in place into a caller-provided buffer (hot-path variant,
    /// avoids allocation).
    pub fn apply_into<T: Copy>(&self, x: &[T], out: &mut [T]) {
        assert_eq!(x.len(), self.len());
        assert_eq!(out.len(), self.len());
        for (i, &v) in x.iter().enumerate() {
            out[self.map[i] as usize] = v;
        }
    }

    /// Permute the rows of a row-major `rows × cols` matrix:
    /// row `i` of the input becomes row `dest(i)` of the output.
    /// This is left-multiplication by the permutation matrix `P`.
    pub fn apply_rows(&self, data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        assert_eq!(rows, self.len());
        assert_eq!(data.len(), rows * cols);
        let mut out = vec![0.0f32; data.len()];
        for i in 0..rows {
            let j = self.map[i] as usize;
            out[j * cols..(j + 1) * cols].copy_from_slice(&data[i * cols..(i + 1) * cols]);
        }
        out
    }

    /// Permute the columns of a row-major `rows × cols` matrix:
    /// column `i` of the input becomes column `dest(i)` of the output.
    /// This is right-multiplication by `Pᵀ` (so `apply_cols` with the same
    /// permutation used for `apply_rows` mirrors the paper's `P B P`).
    pub fn apply_cols(&self, data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        assert_eq!(cols, self.len());
        assert_eq!(data.len(), rows * cols);
        let mut out = vec![0.0f32; data.len()];
        for r in 0..rows {
            let row_in = &data[r * cols..(r + 1) * cols];
            let row_out = &mut out[r * cols..(r + 1) * cols];
            for i in 0..cols {
                row_out[self.map[i] as usize] = row_in[i];
            }
        }
        out
    }

    /// Dense matrix form of the permutation: `P[dest(i)][i] = 1`.
    pub fn to_matrix(&self) -> Vec<f32> {
        let n = self.len();
        let mut m = vec![0.0f32; n * n];
        for i in 0..n {
            m[self.map[i] as usize * n + i] = 1.0;
        }
        m
    }

    /// Cycle decomposition (sorted by smallest member), useful for debugging
    /// and for the decompose round-trip diagnostics.
    pub fn cycles(&self) -> Vec<Vec<usize>> {
        let n = self.len();
        let mut seen = vec![false; n];
        let mut cycles = Vec::new();
        for start in 0..n {
            if seen[start] {
                continue;
            }
            let mut cyc = vec![start];
            seen[start] = true;
            let mut cur = self.map[start] as usize;
            while cur != start {
                seen[cur] = true;
                cyc.push(cur);
                cur = self.map[cur] as usize;
            }
            cycles.push(cyc);
        }
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::prng::Xoshiro256pp;

    #[test]
    fn identity_roundtrip() {
        let p = Permutation::identity(5);
        assert!(p.is_identity());
        assert_eq!(p.inverse(), p);
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(p.apply_vec(&x), x.to_vec());
    }

    #[test]
    fn from_map_rejects_non_bijections() {
        assert!(Permutation::from_map(vec![0, 0, 1]).is_err());
        assert!(Permutation::from_map(vec![0, 3]).is_err());
        assert!(Permutation::from_map(vec![2, 0, 1]).is_ok());
    }

    #[test]
    fn inverse_law() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        for n in [1usize, 2, 7, 100, 301] {
            let p = Permutation::random(n, &mut rng);
            let inv = p.inverse();
            assert!(p.compose(&inv).is_identity());
            assert!(inv.compose(&p).is_identity());
        }
    }

    #[test]
    fn apply_vec_matches_matrix_form() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let n = 13;
        let p = Permutation::random(n, &mut rng);
        let x: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let px = p.apply_vec(&x);
        // matrix-vector product with the dense form
        let m = p.to_matrix();
        let mut mx = vec![0.0f32; n];
        for r in 0..n {
            for c in 0..n {
                mx[r] += m[r * n + c] * x[c];
            }
        }
        assert_eq!(px, mx);
    }

    #[test]
    fn rows_then_inverse_restores() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let (rows, cols) = (6, 4);
        let p = Permutation::random(rows, &mut rng);
        let data: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
        let permd = p.apply_rows(&data, rows, cols);
        let back = p.inverse().apply_rows(&permd, rows, cols);
        assert_eq!(back, data);
    }

    #[test]
    fn cols_then_inverse_restores() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let (rows, cols) = (4, 9);
        let p = Permutation::random(cols, &mut rng);
        let data: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
        let permd = p.apply_cols(&data, rows, cols);
        let back = p.inverse().apply_cols(&permd, rows, cols);
        assert_eq!(back, data);
    }

    #[test]
    fn compose_associates_with_apply() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let n = 11;
        let p = Permutation::random(n, &mut rng);
        let q = Permutation::random(n, &mut rng);
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let a = p.apply_vec(&q.apply_vec(&x));
        let b = p.compose(&q).apply_vec(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn cycles_partition_indices() {
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let n = 20;
        let p = Permutation::random(n, &mut rng);
        let cycles = p.cycles();
        let mut all: Vec<usize> = cycles.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }
}
