//! Int8 packed block-diagonal GEMM — the quantized mirror of
//! [`crate::linalg::blockdiag_mm`].
//!
//! A [`QuantizedBlockDiagMatrix`] stores the same packed block layout as
//! [`BlockDiagMatrix`], but each weight is an `i8` with a symmetric
//! per-block-row scale: `w[r][p] ≈ q[r][p] · row_scales[r]`. Activations are
//! quantized per layer with one symmetric scale (`x ≈ qx · act_scale`), so a
//! block row reduces to an integer dot product
//!
//! ```text
//!   acc[r] = Σ_p qx[p] · q[r][p]            (i8 × i8 → i32, exact)
//!   y[r]   = acc[r] · act_scale · row_scales[r] + bias[r]   (dequant epilogue)
//! ```
//!
//! Because the accumulator is an exact integer, the result is **identical for
//! every tile shape, thread count, and summation order** — the f32 kernel has
//! to enforce a canonical p-order to get that property; here it is free. The
//! tests still pin it down across tile shapes and pooled execution.
//!
//! The kernel mirrors the f32 micro-GEMM's structure: const-generic
//! `TM × TN` register tiles ([`TileShape`], same {1,2,4,8} axes), scalar
//! remainder paths, disjoint per-block output rows, parallel-over-blocks on
//! the persistent [`ThreadPool`]. The dequantize + bias + ReLU epilogue is
//! fused into the tile writeback, so a quantized layer forward writes every
//! output element exactly once.
//!
//! Overflow: `in_b · 127 · 127` must stay below `i32::MAX`, i.e. block input
//! widths up to ~130k columns — far beyond any layer here; checked at
//! construction.

use crate::linalg::blockdiag_mm::{BlockDiagMatrix, TileShape};
use crate::linalg::pool::ThreadPool;
use crate::mask::blockdiag::BlockDiagLayout;

/// Largest quantized magnitude of the symmetric i8 scheme (−127..=127; −128
/// is never produced, keeping negation safe and the range symmetric).
pub const QMAX: f32 = 127.0;

/// Widest block input dimension the i32 accumulator provably cannot overflow.
const MAX_IN_B: usize = (i32::MAX / (127 * 127)) as usize;

/// Symmetric quantization scale covering `[-max_abs, max_abs]` in `QMAX`
/// steps. A zero range yields scale 1.0 (everything quantizes to 0).
#[inline]
pub fn symmetric_scale(max_abs: f32) -> f32 {
    if max_abs > 0.0 {
        max_abs / QMAX
    } else {
        1.0
    }
}

/// Quantize one value: round-half-away-from-zero, clamped to ±127.
#[inline]
pub fn quantize_i8(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-QMAX, QMAX) as i8
}

/// Quantize a slice into a reusable buffer.
pub fn quantize_slice_into(src: &[f32], scale: f32, dst: &mut Vec<i8>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| quantize_i8(v, scale)));
}

/// What the finished integer tile turns into (mirror of the f32 kernel's
/// epilogue, minus the accumulate variant: quantized layers always fuse).
#[derive(Clone, Copy)]
struct QEpilogue {
    act_scale: f32,
    relu: bool,
}

/// Shared raw handle to the f32 output buffer; same aliasing discipline as
/// the f32 kernel's `OutPtr` (each task projects `&mut` only over its own
/// block's disjoint rows, and the pool joins before the caller's borrow
/// resumes).
#[derive(Clone, Copy)]
struct QOutPtr {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: tasks write disjoint row segments (block row spans partition the
// output columns) and the pool joins all tasks before the caller's `&mut` is
// used again; `seg_mut` is the only access path.
unsafe impl Send for QOutPtr {}
unsafe impl Sync for QOutPtr {}

impl QOutPtr {
    /// SAFETY (caller): `[base, base + n)` must not overlap any other live
    /// projection — guaranteed because block row spans are disjoint.
    #[inline]
    unsafe fn seg_mut(&self, base: usize, n: usize) -> &mut [f32] {
        debug_assert!(base + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(base), n)
    }
}

/// Shared raw handle to the i8 panel scratch of the fused path; same
/// aliasing discipline as [`QOutPtr`] (each block task projects only its own
/// disjoint panel slab).
#[derive(Clone, Copy)]
struct QPanelPtr {
    ptr: *mut i8,
    len: usize,
}

// SAFETY: tasks write disjoint slabs (`[b·stride, (b+1)·stride)`) and the
// pool joins all tasks before the caller's `&mut` is used again.
unsafe impl Send for QPanelPtr {}
unsafe impl Sync for QPanelPtr {}

impl QPanelPtr {
    /// SAFETY (caller): `[base, base + n)` must not overlap any other live
    /// projection — guaranteed because panel slabs are disjoint per block.
    #[inline]
    unsafe fn seg_mut(&self, base: usize, n: usize) -> &mut [i8] {
        debug_assert!(base + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(base), n)
    }
}

/// A block-diagonal weight matrix quantized to i8 in packed storage, with
/// symmetric per-block-row scales.
#[derive(Clone, Debug)]
pub struct QuantizedBlockDiagMatrix {
    pub layout: BlockDiagLayout,
    /// Concatenated row-major i8 blocks, same layout as
    /// [`BlockDiagMatrix::packed`].
    pub packed: Vec<i8>,
    pub block_off: Vec<usize>,
    /// One scale per output row, indexed in block-row space (length
    /// `layout.rows`): `w[r][p] ≈ packed-entry · row_scales[r]`.
    pub row_scales: Vec<f32>,
}

impl QuantizedBlockDiagMatrix {
    /// Quantize an f32 packed block-diagonal matrix: per block row, the scale
    /// is `max|w| / 127` and entries round to the nearest step — the rounding
    /// error per weight is at most `row_scales[r] / 2`.
    pub fn from_f32(bd: &BlockDiagMatrix) -> Self {
        let layout = bd.layout.clone();
        let mut row_scales = vec![1.0f32; layout.rows];
        let mut packed = vec![0i8; bd.packed.len()];
        for b in 0..layout.nblocks() {
            let rs = layout.row_spans[b];
            let cs = layout.col_spans[b];
            assert!(
                cs.len <= MAX_IN_B,
                "block {b}: input width {} overflows the i32 accumulator bound {MAX_IN_B}",
                cs.len
            );
            let wb = bd.block(b);
            let off = bd.block_off[b];
            for r in 0..rs.len {
                let row = &wb[r * cs.len..(r + 1) * cs.len];
                let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = symmetric_scale(max_abs);
                row_scales[rs.start + r] = scale;
                for (p, &v) in row.iter().enumerate() {
                    packed[off + r * cs.len + p] = quantize_i8(v, scale);
                }
            }
        }
        Self { layout, packed, block_off: bd.block_off.clone(), row_scales }
    }

    /// Quantize a dense `[rows × cols]` f32 matrix as a single block — how
    /// the quantized model runs its dense (unmasked) layers through the same
    /// kernel.
    pub fn from_dense_f32(w: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(w.len(), rows * cols);
        let layout = BlockDiagLayout::new(rows, cols, 1);
        let bd = BlockDiagMatrix::from_packed(w.to_vec(), layout);
        Self::from_f32(&bd)
    }

    /// Rebuild from serialized parts (checkpoint v2 load path).
    pub fn from_parts(
        layout: BlockDiagLayout,
        packed: Vec<i8>,
        row_scales: Vec<f32>,
    ) -> Result<Self, String> {
        if packed.len() != layout.nnz() {
            return Err(format!("packed len {} != layout nnz {}", packed.len(), layout.nnz()));
        }
        if row_scales.len() != layout.rows {
            return Err(format!("row_scales len {} != rows {}", row_scales.len(), layout.rows));
        }
        if row_scales.iter().any(|s| !s.is_finite() || *s <= 0.0) {
            return Err("row scales must be finite and positive".into());
        }
        if layout.col_spans.iter().any(|c| c.len > MAX_IN_B) {
            return Err(format!("block input width exceeds accumulator bound {MAX_IN_B}"));
        }
        let mut block_off = Vec::with_capacity(layout.nblocks() + 1);
        let mut off = 0;
        for b in 0..layout.nblocks() {
            block_off.push(off);
            off += layout.row_spans[b].len * layout.col_spans[b].len;
        }
        block_off.push(off);
        Ok(Self { layout, packed, block_off, row_scales })
    }

    pub fn nblocks(&self) -> usize {
        self.layout.nblocks()
    }

    /// Stored quantized parameter count.
    pub fn nnz(&self) -> usize {
        self.packed.len()
    }

    /// Bytes of the quantized representation: i8 values, f32 row scales, and
    /// one span pair per block — ~4× below the f32 packed format.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.row_scales.len() * 4 + self.layout.nblocks() * 4 * std::mem::size_of::<u32>()
    }

    /// Block `b` as a row-major `(out_b × in_b)` i8 slice.
    #[inline]
    pub fn block(&self, b: usize) -> &[i8] {
        &self.packed[self.block_off[b]..self.block_off[b + 1]]
    }

    /// Dequantize back to a dense f32 `[rows × cols]` matrix (test helper).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        let mut out = vec![0.0f32; rows * cols];
        for b in 0..self.nblocks() {
            let rs = self.layout.row_spans[b];
            let cs = self.layout.col_spans[b];
            let qb = self.block(b);
            for r in 0..rs.len {
                let scale = self.row_scales[rs.start + r];
                for p in 0..cs.len {
                    out[(rs.start + r) * cols + cs.start + p] = qb[r * cs.len + p] as f32 * scale;
                }
            }
        }
        out
    }

    /// Fused quantized layer forward:
    /// `Y[:, rs_b] = dequant(Xq[:, cs_b] · Qᵀ_b) + bias[rs_b]`, optionally
    /// through ReLU. `xq` is the layer input already quantized with
    /// `act_scale` (`[batch × cols]` row-major i8), `y` is written — not
    /// accumulated; `bias` is f32 in block-row space. Runs on `pool` when
    /// given; exact across tile shapes and thread counts.
    pub fn forward_fused(
        &self,
        xq: &[i8],
        y: &mut [f32],
        batch: usize,
        act_scale: f32,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
    ) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(xq.len(), batch * cols, "Xq shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        let ep = QEpilogue { act_scale, relu };
        let nblocks = self.nblocks();
        let yp = QOutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let parallel = match pool {
            Some(p) => p.lanes() > 1 && nblocks > 1,
            None => false,
        };
        if !parallel {
            for b in 0..nblocks {
                self.block_forward(b, xq, yp, batch, bias, ep, tile);
            }
            return;
        }
        let p = pool.unwrap();
        p.run(nblocks, |b| {
            // SAFETY of sharing yp: block b writes only Y[:, row_spans[b]] —
            // row spans are disjoint across blocks, and the pool joins every
            // task before the borrow of `y` resumes on the caller.
            self.block_forward(b, xq, yp, batch, bias, ep, tile);
        });
    }

    /// [`Self::forward_fused`] with an explicit kernel ISA — the entry the
    /// executor dispatches through. Unlike the f32 engine, the choice never
    /// changes the output bits: i8×i8→i32 accumulation is order-free (and
    /// overflow-free under `MAX_IN_B`) and the SIMD dequant epilogue
    /// reproduces [`dequant`] exactly, so SIMD vs scalar — and any tile or
    /// thread count — are bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_fused_isa(
        &self,
        xq: &[i8],
        y: &mut [f32],
        batch: usize,
        act_scale: f32,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
    ) {
        let _span = crate::obs::span("blockdiag_mm_i8");
        if !isa.is_simd() {
            return self.forward_fused(xq, y, batch, act_scale, bias, relu, pool, tile);
        }
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(xq.len(), batch * cols, "Xq shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        let ep = QEpilogue { act_scale, relu };
        let nblocks = self.nblocks();
        let yp = QOutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let parallel = pool.map(|p| p.lanes() > 1 && nblocks > 1).unwrap_or(false);
        if !parallel {
            for b in 0..nblocks {
                self.block_forward_simd(b, xq, yp, batch, bias, ep, isa);
            }
            return;
        }
        // SAFETY of sharing yp: same argument as forward_fused — disjoint
        // row spans per block, pool joins before the borrow of `y` resumes.
        pool.unwrap().run(nblocks, |b| {
            self.block_forward_simd(b, xq, yp, batch, bias, ep, isa);
        });
    }

    /// SIMD per-block kernel: one vectorized i8 dot per output element, with
    /// the dequant epilogue applied four rows at a time.
    fn block_forward_simd(
        &self,
        b: usize,
        xq: &[i8],
        yp: QOutPtr,
        batch: usize,
        bias: &[f32],
        ep: QEpilogue,
        isa: crate::linalg::kernel::Isa,
    ) {
        use crate::linalg::kernel;
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        let qb = self.block(b);
        let (out_b, in_b) = (rs.len, cs.len);
        for bi in 0..batch {
            let xrow = &xq[bi * cols + cs.start..bi * cols + cs.end()];
            // SAFETY: rows of block b only — disjoint from all other tasks.
            let yrow = unsafe { yp.seg_mut(bi * rows + rs.start, out_b) };
            let mut r = 0;
            while r + 4 <= out_b {
                let accs = [
                    kernel::dot_i8(isa, xrow, &qb[r * in_b..(r + 1) * in_b]),
                    kernel::dot_i8(isa, xrow, &qb[(r + 1) * in_b..(r + 2) * in_b]),
                    kernel::dot_i8(isa, xrow, &qb[(r + 2) * in_b..(r + 3) * in_b]),
                    kernel::dot_i8(isa, xrow, &qb[(r + 3) * in_b..(r + 4) * in_b]),
                ];
                let gr = rs.start + r;
                kernel::dequant4(
                    isa,
                    accs,
                    ep.act_scale,
                    &self.row_scales[gr..gr + 4],
                    &bias[gr..gr + 4],
                    ep.relu,
                    &mut yrow[r..r + 4],
                );
                r += 4;
            }
            while r < out_b {
                let acc = kernel::dot_i8(isa, xrow, &qb[r * in_b..(r + 1) * in_b]);
                let gr = rs.start + r;
                yrow[r] = dequant(acc, ep, self.row_scales[gr], bias[gr]);
                r += 1;
            }
        }
    }

    /// Widest block reduction dimension — the panel column stride of the
    /// fused pack-gather path.
    pub fn max_block_cols(&self) -> usize {
        self.layout.col_spans.iter().map(|c| c.len).max().unwrap_or(0)
    }

    /// Scratch i8 count [`Self::forward_panel_isa`] needs: one
    /// `PANEL_CHUNK`-row slab per block, batch-independent.
    pub fn panel_elems(&self) -> usize {
        self.nblocks() * crate::linalg::blockdiag_mm::PANEL_CHUNK * self.max_block_cols()
    }

    /// Implicit-GEMM fused forward, quantized twin of
    /// [`BlockDiagMatrix::forward_panel_isa`]: A-rows are gathered straight
    /// out of the flat quantized activation `xq` (quantization is
    /// element-wise and `quantize(0) == 0`, so quantize-then-gather equals
    /// gather-then-quantize — including conv padding) into a per-block panel
    /// slab, `PANEL_CHUNK` rows at a time. Integer accumulation keeps the
    /// result bit-identical to the materialized pipeline for every tile
    /// shape, thread count, and ISA.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_panel_isa(
        &self,
        xq: &[i8],
        y: &mut [f32],
        nrows: usize,
        src: &crate::linalg::im2col::PanelSource<'_>,
        act_scale: f32,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
        panel: &mut Vec<i8>,
    ) {
        let _span = crate::obs::span("blockdiag_mm_i8_panel");
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(src.ncols(), cols, "panel source width mismatch");
        assert_eq!(xq.len(), src.src_elems_for(nrows), "source shape mismatch");
        assert_eq!(y.len(), nrows * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        let ep = QEpilogue { act_scale, relu };
        let nblocks = self.nblocks();
        let stride = crate::linalg::blockdiag_mm::PANEL_CHUNK * self.max_block_cols();
        if panel.len() < nblocks * stride {
            panel.resize(nblocks * stride, 0);
        }
        let yp = QOutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let pp = QPanelPtr { ptr: panel.as_mut_ptr(), len: panel.len() };
        let parallel = pool.map(|p| p.lanes() > 1 && nblocks > 1).unwrap_or(false);
        if !parallel {
            for b in 0..nblocks {
                // SAFETY: sequential — one panel projection live at a time.
                let pslice = unsafe { pp.seg_mut(b * stride, stride) };
                self.block_forward_panel(b, xq, yp, nrows, src, bias, ep, tile, isa, pslice);
            }
            return;
        }
        pool.unwrap().run(nblocks, |b| {
            // SAFETY of sharing yp/pp: block b writes only its own output
            // row span and its own `[b·stride, (b+1)·stride)` panel slab —
            // both disjoint across blocks — and the pool joins all tasks
            // before the borrows of `y`/`panel` are used again.
            let pslice = unsafe { pp.seg_mut(b * stride, stride) };
            self.block_forward_panel(b, xq, yp, nrows, src, bias, ep, tile, isa, pslice);
        });
    }

    /// One block of the fused path: pack `PANEL_CHUNK` quantized A-rows of
    /// this block's column span, multiply, repeat. Scalar ISA goes through
    /// the shared tiled micro-kernel; SIMD mirrors
    /// [`Self::block_forward_simd`]'s dot + 4-row dequant groups.
    #[allow(clippy::too_many_arguments)]
    fn block_forward_panel(
        &self,
        b: usize,
        xq: &[i8],
        yp: QOutPtr,
        nrows: usize,
        src: &crate::linalg::im2col::PanelSource<'_>,
        bias: &[f32],
        ep: QEpilogue,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
        pslice: &mut [i8],
    ) {
        use crate::linalg::kernel;
        let rows = self.layout.rows;
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let (out_b, in_b) = (rs.len, cs.len);
        let qb = self.block(b);
        for row0 in (0..nrows).step_by(crate::linalg::blockdiag_mm::PANEL_CHUNK) {
            let nr = crate::linalg::blockdiag_mm::PANEL_CHUNK.min(nrows - row0);
            for i in 0..nr {
                src.pack_row(xq, row0 + i, cs.start, &mut pslice[i * in_b..(i + 1) * in_b]);
            }
            if !isa.is_simd() {
                self.block_forward_at(b, pslice, in_b, 0, yp, row0, nr, bias, ep, tile);
                continue;
            }
            for i in 0..nr {
                let prow = &pslice[i * in_b..(i + 1) * in_b];
                // SAFETY: rows of block b only — disjoint from all other tasks.
                let yrow = unsafe { yp.seg_mut((row0 + i) * rows + rs.start, out_b) };
                let mut r = 0;
                while r + 4 <= out_b {
                    let accs = [
                        kernel::dot_i8(isa, prow, &qb[r * in_b..(r + 1) * in_b]),
                        kernel::dot_i8(isa, prow, &qb[(r + 1) * in_b..(r + 2) * in_b]),
                        kernel::dot_i8(isa, prow, &qb[(r + 2) * in_b..(r + 3) * in_b]),
                        kernel::dot_i8(isa, prow, &qb[(r + 3) * in_b..(r + 4) * in_b]),
                    ];
                    let gr = rs.start + r;
                    kernel::dequant4(
                        isa,
                        accs,
                        ep.act_scale,
                        &self.row_scales[gr..gr + 4],
                        &bias[gr..gr + 4],
                        ep.relu,
                        &mut yrow[r..r + 4],
                    );
                    r += 4;
                }
                while r < out_b {
                    let acc = kernel::dot_i8(isa, prow, &qb[r * in_b..(r + 1) * in_b]);
                    let gr = rs.start + r;
                    yrow[r] = dequant(acc, ep, self.row_scales[gr], bias[gr]);
                    r += 1;
                }
            }
        }
    }

    /// Scalar reference kernel (the oracle the tiled/pooled paths are tested
    /// against — equality is exact, integer accumulation is order-free).
    pub fn forward_fused_reference(
        &self,
        xq: &[i8],
        y: &mut [f32],
        batch: usize,
        act_scale: f32,
        bias: &[f32],
        relu: bool,
    ) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(xq.len(), batch * cols);
        assert_eq!(y.len(), batch * rows);
        assert_eq!(bias.len(), rows);
        let ep = QEpilogue { act_scale, relu };
        for b in 0..self.nblocks() {
            let rs = self.layout.row_spans[b];
            let cs = self.layout.col_spans[b];
            let qb = self.block(b);
            for bi in 0..batch {
                let xrow = &xq[bi * cols + cs.start..bi * cols + cs.end()];
                for r in 0..rs.len {
                    let wrow = &qb[r * cs.len..(r + 1) * cs.len];
                    let mut acc = 0i32;
                    for p in 0..cs.len {
                        acc += xrow[p] as i32 * wrow[p] as i32;
                    }
                    y[bi * rows + rs.start + r] = dequant(acc, ep, self.row_scales[rs.start + r], bias[rs.start + r]);
                }
            }
        }
    }

    /// Per-block kernel entry for the materialized-A path: the block reads
    /// its rows straight out of the full quantized activation matrix
    /// (`ldx = cols`, row offset `cs.start`).
    fn block_forward(
        &self,
        b: usize,
        xq: &[i8],
        yp: QOutPtr,
        batch: usize,
        bias: &[f32],
        ep: QEpilogue,
        tile: TileShape,
    ) {
        let cs = self.layout.col_spans[b];
        self.block_forward_at(b, xq, self.layout.cols, cs.start, yp, 0, batch, bias, ep, tile);
    }

    /// Tile-shape dispatch onto a monomorphized micro-kernel, generalized
    /// over where the block's A-rows live (same `(ldx, xoff, y_row0, nloc)`
    /// addressing as the f32 kernel's `block_forward_at`) so the fused panel
    /// path and the materialized path share one kernel. Integer accumulation
    /// is order-free, so this sharing is about code paths, not numerics.
    #[allow(clippy::too_many_arguments)]
    fn block_forward_at(
        &self,
        b: usize,
        xq: &[i8],
        ldx: usize,
        xoff: usize,
        yp: QOutPtr,
        y_row0: usize,
        nloc: usize,
        bias: &[f32],
        ep: QEpilogue,
        tile: TileShape,
    ) {
        match (tile.batch, tile.rows) {
            (1, 1) => self.block_forward_t::<1, 1>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 2) => self.block_forward_t::<1, 2>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 4) => self.block_forward_t::<1, 4>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 8) => self.block_forward_t::<1, 8>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 1) => self.block_forward_t::<2, 1>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 2) => self.block_forward_t::<2, 2>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 4) => self.block_forward_t::<2, 4>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 8) => self.block_forward_t::<2, 8>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 1) => self.block_forward_t::<4, 1>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 2) => self.block_forward_t::<4, 2>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 4) => self.block_forward_t::<4, 4>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 8) => self.block_forward_t::<4, 8>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 1) => self.block_forward_t::<8, 1>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 2) => self.block_forward_t::<8, 2>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 4) => self.block_forward_t::<8, 4>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 8) => self.block_forward_t::<8, 8>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep),
            _ => {
                debug_assert!(false, "unvalidated tile shape {tile:?}");
                self.block_forward_t::<4, 8>(b, xq, ldx, xoff, yp, y_row0, nloc, bias, ep)
            }
        }
    }

    /// The tiled integer micro-GEMM over one block, `TM × TN` register tiles
    /// of i32 accumulators.
    #[allow(clippy::too_many_arguments)]
    fn block_forward_t<const TM: usize, const TN: usize>(
        &self,
        b: usize,
        xq: &[i8],
        ldx: usize,
        xoff: usize,
        yp: QOutPtr,
        y_row0: usize,
        nloc: usize,
        bias: &[f32],
        ep: QEpilogue,
    ) {
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let rows = self.layout.rows;
        let qb = self.block(b); // (rs.len × cs.len), row-major i8
        let (out_b, in_b) = (rs.len, cs.len);
        let mb = nloc - nloc % TM;
        let nb = out_b - out_b % TN;

        for bi0 in (0..mb).step_by(TM) {
            for r0 in (0..nb).step_by(TN) {
                let mut xrows = [&xq[..0]; TM];
                for (i, xr) in xrows.iter_mut().enumerate() {
                    let base = xoff + (bi0 + i) * ldx;
                    *xr = &xq[base..base + in_b];
                }
                let mut wrows = [&qb[..0]; TN];
                for (j, wr) in wrows.iter_mut().enumerate() {
                    *wr = &qb[(r0 + j) * in_b..(r0 + j + 1) * in_b];
                }
                let mut acc = [[0i32; TN]; TM];
                for p in 0..in_b {
                    for i in 0..TM {
                        let xv = xrows[i][p] as i32;
                        for j in 0..TN {
                            acc[i][j] += xv * wrows[j][p] as i32;
                        }
                    }
                }
                for i in 0..TM {
                    let base = (y_row0 + bi0 + i) * rows + rs.start + r0;
                    // SAFETY: rows of this block only — disjoint across tasks.
                    let yrow = unsafe { yp.seg_mut(base, TN) };
                    for j in 0..TN {
                        let gr = rs.start + r0 + j;
                        yrow[j] = dequant(acc[i][j], ep, self.row_scales[gr], bias[gr]);
                    }
                }
            }
        }
        // Remainder regions (same split as the f32 kernel):
        //   A: full-tile batch rows × leftover output rows
        //   B: leftover batch rows × all output rows
        if nb < out_b {
            self.block_scalar(b, xq, ldx, xoff, yp, y_row0, bias, ep, 0..mb, nb..out_b);
        }
        if mb < nloc {
            self.block_scalar(b, xq, ldx, xoff, yp, y_row0, bias, ep, mb..nloc, 0..out_b);
        }
    }

    /// Scalar cell path for tile remainders (and the 1×1 "tile").
    #[allow(clippy::too_many_arguments)]
    fn block_scalar(
        &self,
        b: usize,
        xq: &[i8],
        ldx: usize,
        xoff: usize,
        yp: QOutPtr,
        y_row0: usize,
        bias: &[f32],
        ep: QEpilogue,
        bi_range: std::ops::Range<usize>,
        r_range: std::ops::Range<usize>,
    ) {
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let rows = self.layout.rows;
        let qb = self.block(b);
        let in_b = cs.len;
        for bi in bi_range {
            let xrow = &xq[xoff + bi * ldx..xoff + bi * ldx + in_b];
            for r in r_range.clone() {
                let wrow = &qb[r * in_b..(r + 1) * in_b];
                let mut acc = 0i32;
                for p in 0..in_b {
                    acc += xrow[p] as i32 * wrow[p] as i32;
                }
                let gr = rs.start + r;
                let idx = (y_row0 + bi) * rows + gr;
                // SAFETY: a cell of this block's own rows — disjoint across tasks.
                let cell = unsafe { yp.seg_mut(idx, 1) };
                cell[0] = dequant(acc, ep, self.row_scales[gr], bias[gr]);
            }
        }
    }
}

/// The dequantize + bias + ReLU epilogue applied to one finished integer
/// accumulator. The scale product runs in f64 so the epilogue's own rounding
/// stays far below the quantization error the bound accounts for; every code
/// path (tiled, scalar remainder, reference — and, bit-for-bit, the SIMD
/// `kernel::dequant4`) funnels through the single definition in
/// `kernel::dequant_one`, which is what makes cross-path equality exact.
#[inline]
fn dequant(acc: i32, ep: QEpilogue, row_scale: f32, bias: f32) -> f32 {
    crate::linalg::kernel::dequant_one(acc, ep.act_scale, row_scale, bias, ep.relu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::prng::Xoshiro256pp;

    fn mk(rows: usize, cols: usize, k: usize, rng: &mut Xoshiro256pp) -> BlockDiagMatrix {
        let layout = BlockDiagLayout::new(rows, cols, k);
        let mut packed = Vec::with_capacity(layout.nnz());
        for _ in 0..layout.nnz() {
            packed.push(rng.next_f32() * 2.0 - 1.0);
        }
        BlockDiagMatrix::from_packed(packed, layout)
    }

    fn quantize_input(x: &[f32]) -> (Vec<i8>, f32) {
        let max_abs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = symmetric_scale(max_abs);
        let mut q = Vec::new();
        quantize_slice_into(x, s, &mut q);
        (q, s)
    }

    #[test]
    fn quantization_error_bounded_per_weight() {
        let mut rng = Xoshiro256pp::seed_from_u64(71);
        let bd = mk(40, 30, 4, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        assert_eq!(qbd.nnz(), bd.nnz());
        let dense = bd.to_dense();
        let deq = qbd.to_dense_f32();
        for r in 0..40 {
            let s = qbd.row_scales[r];
            assert!(s > 0.0);
            for c in 0..30 {
                let err = (dense[r * 30 + c] - deq[r * 30 + c]).abs();
                assert!(err <= s * 0.5 + 1e-7, "row {r}: err {err} > {}", s * 0.5);
            }
        }
    }

    #[test]
    fn tiled_matches_scalar_reference_exactly() {
        let mut rng = Xoshiro256pp::seed_from_u64(72);
        for (rows, cols, k, batch) in [(13, 9, 3, 1), (300, 784, 10, 32), (40, 40, 5, 6), (7, 7, 7, 9)] {
            let bd = mk(rows, cols, k, &mut rng);
            let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
            let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32() - 0.5).collect();
            let (xq, s) = quantize_input(&x);
            let bias: Vec<f32> = (0..rows).map(|_| rng.next_f32() - 0.5).collect();
            for relu in [false, true] {
                let mut y_ref = vec![0.0f32; batch * rows];
                qbd.forward_fused_reference(&xq, &mut y_ref, batch, s, &bias, relu);
                for (tm, tn) in [(1, 1), (1, 8), (2, 4), (4, 8), (8, 2), (8, 8)] {
                    let tile = TileShape { batch: tm, rows: tn };
                    let mut y = vec![0.0f32; batch * rows];
                    qbd.forward_fused(&xq, &mut y, batch, s, &bias, relu, None, tile);
                    assert_eq!(y, y_ref, "{rows}x{cols} k={k} b={batch} tile {tm}x{tn} relu={relu}");
                }
            }
        }
    }

    #[test]
    fn pooled_matches_sequential_exactly() {
        let mut rng = Xoshiro256pp::seed_from_u64(73);
        let bd = mk(120, 90, 6, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 90).map(|_| rng.next_f32()).collect();
        let (xq, s) = quantize_input(&x);
        let bias: Vec<f32> = (0..120).map(|_| rng.next_f32() - 0.5).collect();
        let mut y_seq = vec![0.0f32; batch * 120];
        qbd.forward_fused(&xq, &mut y_seq, batch, s, &bias, true, None, TileShape::DEFAULT);
        for nthreads in [2, 3, 8] {
            let pool = ThreadPool::new(nthreads);
            let mut y_par = vec![0.0f32; batch * 120];
            qbd.forward_fused(&xq, &mut y_par, batch, s, &bias, true, Some(&pool), TileShape::DEFAULT);
            assert_eq!(y_seq, y_par, "nthreads={nthreads}");
        }
    }

    #[test]
    fn dequantized_output_tracks_f32_kernel() {
        // |y_q - y_f32| ≤ Σ_p |ŵ|·(s_x/2) + (s_w/2)·|x_p|  per output row
        // (the single-layer dequantization error bound; no propagated error).
        let mut rng = Xoshiro256pp::seed_from_u64(74);
        let (rows, cols, k, batch) = (60, 44, 4, 3);
        let bd = mk(rows, cols, k, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let (xq, s_x) = quantize_input(&x);
        let bias: Vec<f32> = (0..rows).map(|_| rng.next_f32() - 0.5).collect();

        let mut y_f = vec![0.0f32; batch * rows];
        bd.forward_fused(&x, &mut y_f, batch, &bias, false, None, TileShape::DEFAULT);
        let mut y_q = vec![0.0f32; batch * rows];
        qbd.forward_fused(&xq, &mut y_q, batch, s_x, &bias, false, None, TileShape::DEFAULT);

        let deq = qbd.to_dense_f32();
        for bi in 0..batch {
            for b in 0..qbd.nblocks() {
                let rs = qbd.layout.row_spans[b];
                let cs = qbd.layout.col_spans[b];
                for r in rs.start..rs.end() {
                    let s_w = qbd.row_scales[r];
                    let mut bound = 0.0f64;
                    for c in cs.start..cs.end() {
                        let aw = deq[r * cols + c].abs() as f64;
                        bound += aw * (s_x as f64 * 0.5) + (s_w as f64 * 0.5) * x[bi * cols + c].abs() as f64;
                    }
                    let err = (y_f[bi * rows + r] - y_q[bi * rows + r]).abs() as f64;
                    assert!(err <= bound * 1.001 + 1e-4, "row {r}: err {err} > bound {bound}");
                }
            }
        }
    }

    #[test]
    fn from_parts_validates() {
        let mut rng = Xoshiro256pp::seed_from_u64(75);
        let bd = mk(12, 8, 2, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        let rebuilt = QuantizedBlockDiagMatrix::from_parts(
            qbd.layout.clone(),
            qbd.packed.clone(),
            qbd.row_scales.clone(),
        )
        .unwrap();
        assert_eq!(rebuilt.block_off, qbd.block_off);
        assert_eq!(rebuilt.to_dense_f32(), qbd.to_dense_f32());
        // wrong lengths and bad scales rejected
        assert!(QuantizedBlockDiagMatrix::from_parts(
            qbd.layout.clone(),
            vec![0i8; 3],
            qbd.row_scales.clone()
        )
        .is_err());
        assert!(QuantizedBlockDiagMatrix::from_parts(
            qbd.layout.clone(),
            qbd.packed.clone(),
            vec![0.0; 12]
        )
        .is_err());
    }

    #[test]
    fn panel_fused_is_bit_identical_to_materialized() {
        // quantize → gather → forward vs quantize → fused panel forward must
        // be exactly equal: quantization is element-wise, so the gathered
        // panel holds the same i8 values, and i32 accumulation is order-free.
        use crate::linalg::im2col::PanelSource;
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let (rows, cols, k, batch) = (40, 30, 4, 9);
        let bd = mk(rows, cols, k, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        let src_dim = cols + 5;
        let mut idx: Vec<u32> = (0..cols as u32).collect();
        for i in (1..idx.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            idx.swap(i, j);
        }
        let x: Vec<f32> = (0..batch * src_dim).map(|_| rng.next_f32() - 0.5).collect();
        let (xq, s) = quantize_input(&x);
        let bias: Vec<f32> = (0..rows).map(|_| rng.next_f32() - 0.5).collect();
        // materialized reference: gather the quantized source, then forward
        let mut xg = vec![0i8; batch * cols];
        for bi in 0..batch {
            for (c, &sc) in idx.iter().enumerate() {
                xg[bi * cols + c] = xq[bi * src_dim + sc as usize];
            }
        }
        let mut y_ref = vec![0.0f32; batch * rows];
        qbd.forward_fused_reference(&xg, &mut y_ref, batch, s, &bias, true);
        let src = PanelSource::Gather { idx: &idx, src_dim };
        let isas = [
            crate::linalg::kernel::Isa::Scalar,
            crate::linalg::kernel::KernelChoice::auto().i8_isa(),
        ];
        for isa in isas {
            for (tm, tn) in [(1, 1), (2, 8), (4, 8), (8, 2)] {
                let tile = TileShape { batch: tm, rows: tn };
                for lanes in [0usize, 2, 8] {
                    let pool = if lanes == 0 { None } else { Some(ThreadPool::new(lanes)) };
                    let mut y = vec![0.0f32; batch * rows];
                    let mut panel = Vec::new();
                    qbd.forward_panel_isa(
                        &xq, &mut y, batch, &src, s, &bias, true, pool.as_ref(), tile, isa,
                        &mut panel,
                    );
                    assert_eq!(y, y_ref, "isa={isa:?} tile={tm}x{tn} lanes={lanes}");
                    assert_eq!(panel.len(), qbd.panel_elems());
                }
            }
        }
    }

    #[test]
    fn storage_is_quarter_of_f32() {
        let mut rng = Xoshiro256pp::seed_from_u64(76);
        let bd = mk(300, 100, 10, &mut rng);
        let qbd = QuantizedBlockDiagMatrix::from_f32(&bd);
        // 3000 i8 + 300 f32 scales + spans vs 3000 f32 + spans
        assert!(qbd.storage_bytes() * 7 < bd.storage_bytes() * 3, "{} vs {}", qbd.storage_bytes(), bd.storage_bytes());
    }
}
