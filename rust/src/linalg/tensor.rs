//! Minimal row-major f32 matrix/tensor types used across the native engine.
//!
//! We deliberately keep this small: shapes are explicit `(rows, cols)` pairs
//! for 2-D work and a `Vec<usize>` for N-D activations; data is always a flat
//! contiguous `Vec<f32>`. All hot-path kernels (`gemm`, `blockdiag_mm`, conv)
//! operate on raw slices so the types here never get in the way of
//! vectorization.

use std::fmt;

/// Dense row-major 2-D matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        // simple cache-blocked transpose
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Max |a-b| over elements — test helper.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for r in 0..show_r {
            write!(f, "  ")?;
            for c in 0..show_c {
                write!(f, "{:8.4} ", self.get(r, c))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// N-dimensional activation tensor (contiguous, row-major / C order).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// View as a 2-D matrix collapsing all but the last dim into rows.
    pub fn as_matrix(&self) -> Matrix {
        let cols = *self.shape.last().expect("tensor has no dims");
        Matrix::from_vec(self.numel() / cols, cols, self.data.clone())
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>(), "reshape numel mismatch");
        self.shape = shape.to_vec();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_get_set() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(7, 5, |r, c| (r * 5 + c) as f32);
        let t = m.transpose();
        assert_eq!(t.rows, 5);
        assert_eq!(t.get(2, 3), m.get(3, 2));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_blocked_matches_naive_on_large() {
        let m = Matrix::from_fn(100, 67, |r, c| (r * 67 + c) as f32);
        let t = m.transpose();
        for r in 0..100 {
            for c in 0..67 {
                assert_eq!(t.get(c, r), m.get(r, c));
            }
        }
    }

    #[test]
    fn identity_frobenius() {
        let i = Matrix::identity(9);
        assert!((i.frobenius() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn tensor_reshape_and_matrix_view() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|i| i as f32).collect());
        let m = t.as_matrix();
        assert_eq!(m.rows, 6);
        assert_eq!(m.cols, 4);
        let r = t.reshape(&[4, 6]);
        assert_eq!(r.shape, vec![4, 6]);
    }

    #[test]
    #[should_panic]
    fn reshape_rejects_bad_numel() {
        Tensor::zeros(&[2, 3]).reshape(&[7]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
