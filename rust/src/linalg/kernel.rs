//! SIMD micro-kernel registry (ISSUE 6): runtime ISA detection, the
//! `MPDC_FORCE_SCALAR` override, and the per-ISA inner kernels the executor
//! dispatches to — f32 dot products, i8×i8→i32 dot products, the fused
//! dequant+bias+ReLU epilogue, and the im2col column gather.
//!
//! ## Dispatch contract
//!
//! A [`KernelChoice`] is resolved **once**, when an [`crate::exec::Executor`]
//! is built (`KernelChoice::auto()` by default, `scalar()` when the
//! `[engine] simd = false` config knob or the `MPDC_FORCE_SCALAR` env var is
//! set). The hot path never re-detects features and never reads the
//! environment — `leak_test` pins `run_into` at exactly zero allocations and
//! `std::env::var` allocates, so the env flag is read through a `OnceLock`.
//!
//! `Isa` values only ever come from the validated constructors below
//! (`scalar`/`detected`/`auto`), so every SIMD entry point's
//! `#[target_feature]` precondition is established at construction time.
//! Fields of [`KernelChoice`] are private for exactly this reason.
//!
//! ## Pinned f32 accumulation order
//!
//! Every f32 SIMD dot kernel uses the same shape: **two lane-strided vector
//! partial sums** (`v0`, `v1`, fed by FMA in strides of `2·W` where `W` is
//! the vector width), folded as `v0 + v1`, then a **fixed horizontal
//! reduction** (pairwise within the register, documented per ISA below), and
//! finally a scalar tail in ascending `p`. This order is deterministic for a
//! given ISA and input length — independent of tile shape, thread count and
//! batch — so SIMD results are bit-stable run-to-run; they differ from the
//! scalar oracle (strictly ascending-`p` accumulation) only by the
//! reassociation error bounded in [`f32_reorder_bound`].
//!
//! i8 kernels accumulate exactly in i32 (order-free: `MAX_IN_B` caps the
//! block inner dimension so no partial sum can overflow), and the dequant
//! epilogue reproduces the scalar f64-product rounding bit-for-bit, so the
//! whole int8 path is bit-identical to the scalar oracle.

use std::sync::OnceLock;

/// Instruction set a kernel is compiled for. Only constructed by the
/// validated [`KernelChoice`] constructors; `Scalar` is always available.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar oracle (ascending-`p` accumulation).
    Scalar,
    /// x86-64 AVX2 + FMA: 8-lane f32 FMA dots, 16-lane i8 `madd` dots,
    /// 4-lane f64 dequant epilogue, 8-lane `i32gather` column gather.
    Avx2Fma,
    /// x86-64 AVX-512F: 16-lane f32 FMA dots (i8 + epilogue stay on the
    /// AVX2 forms, which every AVX-512F host also provides).
    Avx512f,
    /// aarch64 NEON: 4-lane f32 FMA dots, 8-lane `smull`/`sadalp` i8 dots
    /// (dequant epilogue and gather stay scalar — no f64×4 or gather unit).
    Neon,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2+fma",
            Isa::Avx512f => "avx512f",
            Isa::Neon => "neon",
        }
    }

    pub fn is_simd(&self) -> bool {
        *self != Isa::Scalar
    }
}

/// The ISA pair an executor dispatches with: one choice for the f32 kernels
/// (block GEMM + gather), one for the i8 kernels (block GEMM + dequant
/// epilogue). Private fields: values are only built by the constructors, so
/// holding a `KernelChoice` proves the features were detected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelChoice {
    f32_isa: Isa,
    i8_isa: Isa,
}

impl KernelChoice {
    /// The always-available scalar oracle.
    pub fn scalar() -> Self {
        KernelChoice { f32_isa: Isa::Scalar, i8_isa: Isa::Scalar }
    }

    /// Raw runtime feature detection, ignoring `MPDC_FORCE_SCALAR`. Use in
    /// tests that must exercise the SIMD path regardless of environment.
    pub fn detected() -> Self {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                let f32_isa = if is_x86_feature_detected!("avx512f") { Isa::Avx512f } else { Isa::Avx2Fma };
                // i8 madd + dequant epilogue use the AVX2 forms even on
                // AVX-512 hosts: detection above guarantees avx2+fma.
                return KernelChoice { f32_isa, i8_isa: Isa::Avx2Fma };
            }
            KernelChoice::scalar()
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return KernelChoice { f32_isa: Isa::Neon, i8_isa: Isa::Neon };
            }
            KernelChoice::scalar()
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            KernelChoice::scalar()
        }
    }

    /// What `Executor::new` resolves: [`Self::detected`] unless
    /// `MPDC_FORCE_SCALAR` is set to a truthy value (anything but
    /// `""`/`"0"`/`"false"`/`"no"`/`"off"`).
    pub fn auto() -> Self {
        if force_scalar_env() {
            KernelChoice::scalar()
        } else {
            KernelChoice::detected()
        }
    }

    pub fn f32_isa(&self) -> Isa {
        self.f32_isa
    }

    pub fn i8_isa(&self) -> Isa {
        self.i8_isa
    }

    pub fn is_simd(&self) -> bool {
        self.f32_isa.is_simd() || self.i8_isa.is_simd()
    }

    /// Short human-readable form, e.g. `f32=avx2+fma i8=avx2+fma`.
    pub fn describe(&self) -> String {
        format!("f32={} i8={}", self.f32_isa.name(), self.i8_isa.name())
    }
}

/// Cached read of `MPDC_FORCE_SCALAR` (the env lookup allocates, so it runs
/// at most once per process — never on the `run_into` hot path).
pub fn force_scalar_env() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| match std::env::var("MPDC_FORCE_SCALAR") {
        Ok(v) => !matches!(v.trim(), "" | "0" | "false" | "no" | "off"),
        Err(_) => false,
    })
}

/// The SIMD features this host actually reports, for bench provenance
/// (`results/BENCH_6.json` records them so snapshots are comparable).
pub fn cpu_features() -> Vec<&'static str> {
    #[allow(unused_mut)]
    let mut feats: Vec<&'static str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("avx2", is_x86_feature_detected!("avx2")),
            ("fma", is_x86_feature_detected!("fma")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
        ] {
            if have {
                feats.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            feats.push("neon");
        }
    }
    feats
}

// ---------------------------------------------------------------------------
// f32 dot kernels
// ---------------------------------------------------------------------------

/// Unit roundoff of f32 (half an ULP at 1.0): `2^-24`.
pub const F32_UNIT_ROUNDOFF: f64 = f32::EPSILON as f64 / 2.0;

/// Per-element factor of the analytic bound on `|simd_dot − scalar_dot|`
/// for a length-`n` f32 dot product: multiply by `Σ_p |x_p|·|w_p|`.
///
/// Derivation: both the scalar oracle and every SIMD kernel compute some
/// summation order of the same `n` products (FMA only *removes* product
/// roundings). Standard forward error analysis gives, for either order,
/// `|ŝ − s_exact| ≤ γ_{n+1} · Σ|x_p w_p|` with `γ_k = k·u/(1−k·u) ≈ k·u`,
/// `u = 2^-24`. Triangle inequality across the two orders, plus slack for
/// the bias add and the epilogue, gives `|simd − scalar| ≤ 2(n+4)·u·Σ|x w|`.
pub fn f32_reorder_bound(n: usize) -> f32 {
    (2.0 * (n as f64 + 4.0) * F32_UNIT_ROUNDOFF) as f32
}

/// Dot product of two equal-length f32 slices under the given ISA.
///
/// `Scalar` is the oracle order (strictly ascending `p`); SIMD ISAs use the
/// pinned lane-strided order documented at module level.
#[inline]
pub fn dot_f32(isa: Isa, x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    match isa {
        Isa::Scalar => dot_f32_scalar(x, w),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma/Avx512f only reach here via KernelChoice::detected.
        Isa::Avx2Fma => unsafe { dot_f32_avx2(x, w) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512f => unsafe { dot_f32_avx512(x, w) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { dot_f32_neon(x, w) },
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        _ => dot_f32_scalar(x, w),
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => dot_f32_scalar(x, w),
    }
}

/// The scalar oracle: ascending-`p` accumulation, two roundings per term —
/// exactly the order `block_forward_t` and `block_scalar` use.
#[inline]
pub fn dot_f32_scalar(x: &[f32], w: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for p in 0..x.len() {
        acc += x[p] * w[p];
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_f32_avx2(x: &[f32], w: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    // two lane-strided partials: v0 takes p ≡ 0..8 (mod 16), v1 takes 8..16
    let mut v0 = _mm256_setzero_ps();
    let mut v1 = _mm256_setzero_ps();
    let mut p = 0;
    while p + 16 <= n {
        v0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(p)), _mm256_loadu_ps(wp.add(p)), v0);
        v1 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(p + 8)), _mm256_loadu_ps(wp.add(p + 8)), v1);
        p += 16;
    }
    if p + 8 <= n {
        v0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(p)), _mm256_loadu_ps(wp.add(p)), v0);
        p += 8;
    }
    // fixed horizontal reduction: (lo128 + hi128), then movehl fold, then
    // lane-1 shuffle fold — pinned so results are reproducible run-to-run
    let mut acc = hsum256_f32(_mm256_add_ps(v0, v1));
    while p < n {
        acc += *xp.add(p) * *wp.add(p);
        p += 1;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum256_f32(v: std::arch::x86_64::__m256) -> f32 {
    use std::arch::x86_64::*;
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
    _mm_cvtss_f32(s)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dot_f32_avx512(x: &[f32], w: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    let mut v0 = _mm512_setzero_ps();
    let mut v1 = _mm512_setzero_ps();
    let mut p = 0;
    while p + 32 <= n {
        v0 = _mm512_fmadd_ps(_mm512_loadu_ps(xp.add(p)), _mm512_loadu_ps(wp.add(p)), v0);
        v1 = _mm512_fmadd_ps(_mm512_loadu_ps(xp.add(p + 16)), _mm512_loadu_ps(wp.add(p + 16)), v1);
        p += 32;
    }
    if p + 16 <= n {
        v0 = _mm512_fmadd_ps(_mm512_loadu_ps(xp.add(p)), _mm512_loadu_ps(wp.add(p)), v0);
        p += 16;
    }
    // fixed reduction: (q0+q1) + (q2+q3) over 128-bit quarters, then the
    // same movehl/shuffle fold as the AVX2 kernel
    let v = _mm512_add_ps(v0, v1);
    let s = _mm_add_ps(
        _mm_add_ps(_mm512_extractf32x4_ps::<0>(v), _mm512_extractf32x4_ps::<1>(v)),
        _mm_add_ps(_mm512_extractf32x4_ps::<2>(v), _mm512_extractf32x4_ps::<3>(v)),
    );
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
    let mut acc = _mm_cvtss_f32(s);
    while p < n {
        acc += *xp.add(p) * *wp.add(p);
        p += 1;
    }
    acc
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_f32_neon(x: &[f32], w: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    let mut v0 = vdupq_n_f32(0.0);
    let mut v1 = vdupq_n_f32(0.0);
    let mut p = 0;
    while p + 8 <= n {
        v0 = vfmaq_f32(v0, vld1q_f32(xp.add(p)), vld1q_f32(wp.add(p)));
        v1 = vfmaq_f32(v1, vld1q_f32(xp.add(p + 4)), vld1q_f32(wp.add(p + 4)));
        p += 8;
    }
    if p + 4 <= n {
        v0 = vfmaq_f32(v0, vld1q_f32(xp.add(p)), vld1q_f32(wp.add(p)));
        p += 4;
    }
    // fixed reduction: (l0+l2) + (l1+l3)
    let s = vaddq_f32(v0, v1);
    let mut acc = (vgetq_lane_f32::<0>(s) + vgetq_lane_f32::<2>(s))
        + (vgetq_lane_f32::<1>(s) + vgetq_lane_f32::<3>(s));
    while p < n {
        acc += *xp.add(p) * *wp.add(p);
        p += 1;
    }
    acc
}

// ---------------------------------------------------------------------------
// i8 dot kernels (exact: i8×i8→i32, order-free)
// ---------------------------------------------------------------------------

/// Dot product of two equal-length i8 slices, accumulated exactly in i32.
///
/// Exactness argument: every product fits `|x·w| ≤ 127² = 16129`, and the
/// packed format caps block inner dims at `MAX_IN_B = i32::MAX / 127²`, so
/// the total — and a fortiori every lane partial and every `madd` pair sum —
/// stays inside i32. Integer addition is associative, so every ISA returns
/// the same bits.
#[inline]
pub fn dot_i8(isa: Isa, x: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    match isa {
        Isa::Scalar => dot_i8_scalar(x, w),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SIMD variants only reach here via KernelChoice::detected.
        Isa::Avx2Fma | Isa::Avx512f => unsafe { dot_i8_avx2(x, w) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { dot_i8_neon(x, w) },
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        _ => dot_i8_scalar(x, w),
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => dot_i8_scalar(x, w),
    }
}

#[inline]
pub fn dot_i8_scalar(x: &[i8], w: &[i8]) -> i32 {
    let mut acc = 0i32;
    for p in 0..x.len() {
        acc += x[p] as i32 * w[p] as i32;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(x: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    let mut acc = _mm256_setzero_si256();
    let mut p = 0;
    while p + 16 <= n {
        // widen 16×i8 → 16×i16, multiply-add adjacent pairs → 8×i32
        let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(xp.add(p) as *const __m128i));
        let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(p) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xv, wv));
        p += 16;
    }
    let s = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01>(s));
    let mut sum = _mm_cvtsi128_si32(s);
    while p < n {
        sum += *xp.add(p) as i32 * *wp.add(p) as i32;
        p += 1;
    }
    sum
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_i8_neon(x: &[i8], w: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    let mut acc = vdupq_n_s32(0);
    let mut p = 0;
    while p + 8 <= n {
        // 8×i8 widening multiply → 8×i16, pairwise-add-accumulate → 4×i32
        let prod = vmull_s8(vld1_s8(xp.add(p)), vld1_s8(wp.add(p)));
        acc = vpadalq_s16(acc, prod);
        p += 8;
    }
    let mut sum = vaddvq_s32(acc);
    while p < n {
        sum += *xp.add(p) as i32 * *wp.add(p) as i32;
        p += 1;
    }
    sum
}

// ---------------------------------------------------------------------------
// Fused dequant + bias + ReLU epilogue
// ---------------------------------------------------------------------------

/// The scalar dequantization epilogue — the single definition every i8 path
/// (scalar or SIMD) must reproduce bit-for-bit:
/// `y = (acc · (act_scale ·_f64 row_scale)) rounded to f32, + bias`, with
/// `relu` clamping strictly negative values to `+0.0` (and leaving `-0.0`
/// and NaN untouched, matching `v < 0.0`).
#[inline]
pub fn dequant_one(acc: i32, act_scale: f32, row_scale: f32, bias: f32, relu: bool) -> f32 {
    let v = (acc as f64 * (act_scale as f64 * row_scale as f64)) as f32 + bias;
    if relu && v < 0.0 {
        0.0
    } else {
        v
    }
}

/// Dequantize four accumulators at once. Bit-identical to four
/// [`dequant_one`] calls on every ISA:
///
/// * i32→f64 conversion is exact; `f64 × f64` and f64→f32 rounding are
///   IEEE round-to-nearest-even in both scalar Rust and `vcvtpd2ps`;
/// * the ReLU uses a `v < 0` compare mask (not `max`), so `-0.0` and NaN
///   propagate exactly as the scalar branch does.
#[inline]
pub fn dequant4(
    isa: Isa,
    accs: [i32; 4],
    act_scale: f32,
    row_scales: &[f32],
    biases: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    debug_assert!(row_scales.len() >= 4 && biases.len() >= 4 && out.len() >= 4);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SIMD variants only reach here via KernelChoice::detected.
        Isa::Avx2Fma | Isa::Avx512f => unsafe {
            dequant4_avx2(accs, act_scale, row_scales, biases, relu, out)
        },
        _ => {
            for j in 0..4 {
                out[j] = dequant_one(accs[j], act_scale, row_scales[j], biases[j], relu);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequant4_avx2(
    accs: [i32; 4],
    act_scale: f32,
    row_scales: &[f32],
    biases: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let acc_d = _mm256_cvtepi32_pd(_mm_set_epi32(accs[3], accs[2], accs[1], accs[0]));
    let scale_d = _mm256_mul_pd(
        _mm256_set1_pd(act_scale as f64),
        _mm256_cvtps_pd(_mm_loadu_ps(row_scales.as_ptr())),
    );
    let v = _mm_add_ps(
        _mm256_cvtpd_ps(_mm256_mul_pd(acc_d, scale_d)),
        _mm_loadu_ps(biases.as_ptr()),
    );
    let v = if relu {
        // zero exactly the lanes with v < 0.0 — keeps -0.0 and NaN like the
        // scalar `if v < 0.0` branch (a max would flip -0.0 to +0.0)
        _mm_andnot_ps(_mm_cmplt_ps(v, _mm_setzero_ps()), v)
    } else {
        v
    };
    _mm_storeu_ps(out.as_mut_ptr(), v);
}

// ---------------------------------------------------------------------------
// Column gather (exact: pure copy, any ISA)
// ---------------------------------------------------------------------------

/// Gather `dst[j] = src[idx[j]]` for one row. Exact on every ISA (a gather
/// moves bits, it never rounds). Caller must have bounds-checked `idx`
/// against `src.len()` — `gather_cols` in `im2col.rs` asserts once per call.
#[inline]
pub fn gather_row_f32(isa: Isa, src: &[f32], idx: &[u32], dst: &mut [f32]) {
    debug_assert_eq!(idx.len(), dst.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SIMD variants only via KernelChoice::detected; idx was
        // bounds-checked by the caller per the function contract.
        Isa::Avx2Fma | Isa::Avx512f => unsafe { gather_row_avx2(src, idx, dst) },
        _ => {
            for (d, &s) in dst.iter_mut().zip(idx) {
                *d = src[s as usize];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_row_avx2(src: &[f32], idx: &[u32], dst: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = idx.len();
    let mut j = 0;
    while j + 8 <= n {
        let iv = _mm256_loadu_si256(idx.as_ptr().add(j) as *const __m256i);
        let g = _mm256_i32gather_ps::<4>(src.as_ptr(), iv);
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), g);
        j += 8;
    }
    while j < n {
        *dst.get_unchecked_mut(j) = *src.get_unchecked(*idx.get_unchecked(j) as usize);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    fn rand_f32(state: &mut u64, n: usize) -> Vec<f32> {
        (0..n).map(|_| (xorshift(state) % 2000) as f32 / 500.0 - 2.0).collect()
    }

    fn rand_i8(state: &mut u64, n: usize) -> Vec<i8> {
        (0..n).map(|_| (xorshift(state) % 255) as i8).collect()
    }

    #[test]
    fn constructors_are_consistent() {
        let s = KernelChoice::scalar();
        assert_eq!(s.f32_isa(), Isa::Scalar);
        assert_eq!(s.i8_isa(), Isa::Scalar);
        assert!(!s.is_simd());
        // auto is either scalar (forced / unsupported host) or detected
        let a = KernelChoice::auto();
        assert!(a == KernelChoice::scalar() || a == KernelChoice::detected());
        let d = KernelChoice::detected();
        assert!(d.describe().starts_with("f32="));
    }

    #[test]
    fn f32_dot_within_reorder_bound_on_remainder_lengths() {
        let d = KernelChoice::detected();
        let mut st = 0x12345u64;
        // deliberately awkward lengths around every vector width
        for n in [0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 23, 31, 32, 33, 63, 100, 257] {
            let x = rand_f32(&mut st, n);
            let w = rand_f32(&mut st, n);
            let want = dot_f32_scalar(&x, &w);
            let got = dot_f32(d.f32_isa(), &x, &w);
            let mag: f32 = x.iter().zip(&w).map(|(a, b)| (a * b).abs()).sum();
            let bound = f32_reorder_bound(n) * mag;
            assert!(
                (got - want).abs() <= bound + 1e-12,
                "n={n}: |{got} - {want}| > {bound}"
            );
        }
    }

    #[test]
    fn i8_dot_bit_identical_on_remainder_lengths() {
        let d = KernelChoice::detected();
        let mut st = 0xBEEFu64;
        for n in [0, 1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 255] {
            let x = rand_i8(&mut st, n);
            let w = rand_i8(&mut st, n);
            assert_eq!(dot_i8(d.i8_isa(), &x, &w), dot_i8_scalar(&x, &w), "n={n}");
        }
    }

    #[test]
    fn dequant4_bit_identical_incl_negzero_and_relu() {
        let d = KernelChoice::detected();
        let mut st = 0xD00Du64;
        for _ in 0..200 {
            let accs = [
                xorshift(&mut st) as i32 % 100_000,
                xorshift(&mut st) as i32 % 100_000,
                xorshift(&mut st) as i32 % 100_000,
                xorshift(&mut st) as i32 % 100_000,
            ];
            let act = (xorshift(&mut st) % 1000) as f32 / 997.0 + 1e-4;
            let rs = rand_f32(&mut st, 4).iter().map(|v| v.abs() + 1e-4).collect::<Vec<_>>();
            let bias = rand_f32(&mut st, 4);
            for relu in [false, true] {
                let mut got = [0.0f32; 4];
                dequant4(d.i8_isa(), accs, act, &rs, &bias, relu, &mut got);
                for j in 0..4 {
                    let want = dequant_one(accs[j], act, rs[j], bias[j], relu);
                    assert_eq!(got[j].to_bits(), want.to_bits(), "lane {j} relu={relu}");
                }
            }
        }
        // -0.0 edge: acc 0 with -0.0 bias must survive ReLU with sign intact
        let mut got = [1.0f32; 4];
        dequant4(d.i8_isa(), [0, 0, 0, 0], 0.5, &[1.0; 4], &[-0.0; 4], true, &mut got);
        for j in 0..4 {
            let want = dequant_one(0, 0.5, 1.0, -0.0, true);
            assert_eq!(got[j].to_bits(), want.to_bits(), "-0.0 lane {j}");
        }
    }

    #[test]
    fn gather_row_matches_scalar_copy() {
        let d = KernelChoice::detected();
        let mut st = 0xF00Du64;
        let src = rand_f32(&mut st, 300);
        for n in [0, 1, 3, 7, 8, 9, 16, 25, 64, 129] {
            let idx: Vec<u32> = (0..n).map(|_| (xorshift(&mut st) % 300) as u32).collect();
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            gather_row_f32(d.f32_isa(), &src, &idx, &mut got);
            for (w, &s) in want.iter_mut().zip(&idx) {
                *w = src[s as usize];
            }
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn force_scalar_env_parses_truthiness() {
        // can't mutate the process env reliably under the cached OnceLock;
        // just pin the parse rule the cache applies
        let truthy = |v: &str| !matches!(v.trim(), "" | "0" | "false" | "no" | "off");
        assert!(truthy("1"));
        assert!(truthy("yes"));
        assert!(!truthy("0"));
        assert!(!truthy(""));
        assert!(!truthy("off"));
    }
}
