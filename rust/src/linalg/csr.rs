//! Compressed Sparse Row storage — the *irregular* sparsity baseline.
//!
//! The paper's §1/§2 motivation: magnitude pruning (Han et al. '15) leaves
//! non-zeros scattered irregularly, so inference needs index arrays and
//! gathers ("the processor would need to be alerted with extra flags and
//! pointers"), eroding the compression/speed win. We implement CSR honestly —
//! including its index-memory overhead accounting — so the §3.3 speedup
//! benches compare MPD's packed blocks against a real irregular-sparse
//! competitor rather than a strawman.

/// CSR sparse matrix (f32 values, u32 indices).
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// len rows+1, row r occupies values[indptr[r]..indptr[r+1]]
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense row-major matrix, keeping entries with |v| > 0.
    pub fn from_dense(data: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = data[r * cols + c];
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Self { rows, cols, indptr, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Total bytes of the CSR representation (values + column indices +
    /// indptr). This is what "compression rate" must be charged against for
    /// irregular pruning — the paper's point about flags and pointers.
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 4
    }

    /// Reconstruct the dense matrix (test helper).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for i in s..e {
                out[r * self.cols + self.indices[i] as usize] = self.values[i];
            }
        }
        out
    }

    /// `y += A·x` sparse matrix–vector product.
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            let mut acc = 0.0f32;
            for i in s..e {
                // irregular gather on x — the access pattern the paper
                // identifies as hostile to block-based hardware
                acc += self.values[i] * x[self.indices[i] as usize];
            }
            y[r] += acc;
        }
    }

    /// `C += A·B` with dense row-major `B[cols×n]`, `C[rows×n]` (batched
    /// inference with batch as columns).
    pub fn spmm(&self, b: &[f32], c: &mut [f32], n: usize) {
        assert_eq!(b.len(), self.cols * n);
        assert_eq!(c.len(), self.rows * n);
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            let crow = &mut c[r * n..(r + 1) * n];
            for i in s..e {
                let v = self.values[i];
                let brow = &b[self.indices[i] as usize * n..(self.indices[i] as usize + 1) * n];
                for j in 0..n {
                    crow[j] += v * brow[j];
                }
            }
        }
    }

    /// `C += B·Aᵀ` with dense `B[m×cols_A_T = rows]`… more useful form for
    /// activations-row-major: given X[batch×cols] compute Y[batch×rows] with
    /// Y = X·Aᵀ (A is the `[out×in]` weight matrix). Irregular scatter form.
    pub fn spmm_xt(&self, x: &[f32], y: &mut [f32], batch: usize) {
        assert_eq!(x.len(), batch * self.cols);
        assert_eq!(y.len(), batch * self.rows);
        for bi in 0..batch {
            let xrow = &x[bi * self.cols..(bi + 1) * self.cols];
            let yrow = &mut y[bi * self.rows..(bi + 1) * self.rows];
            for r in 0..self.rows {
                let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
                let mut acc = 0.0f32;
                for i in s..e {
                    acc += self.values[i] * xrow[self.indices[i] as usize];
                }
                yrow[r] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_naive;
    use crate::mask::prng::Xoshiro256pp;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Xoshiro256pp) -> Vec<f32> {
        (0..rows * cols)
            .map(|_| if rng.next_f64() < density { rng.next_f32() * 2.0 - 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let d = sparse_random(20, 30, 0.15, &mut rng);
        let csr = Csr::from_dense(&d, 20, 30);
        assert_eq!(csr.to_dense(), d);
        assert_eq!(csr.nnz(), d.iter().filter(|&&v| v != 0.0).count());
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let (m, k) = (50, 70);
        let d = sparse_random(m, k, 0.1, &mut rng);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let csr = Csr::from_dense(&d, m, k);
        let mut y1 = vec![0.0; m];
        csr.spmv(&x, &mut y1);
        let mut y2 = vec![0.0; m];
        gemm_naive(&d, &x, &mut y2, m, k, 1);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let (m, k, n) = (15, 25, 8);
        let d = sparse_random(m, k, 0.2, &mut rng);
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32()).collect();
        let csr = Csr::from_dense(&d, m, k);
        let mut c1 = vec![0.0; m * n];
        csr.spmm(&b, &mut c1, n);
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&d, &b, &mut c2, m, k, n);
        for (a, b) in c1.iter().zip(&c2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn spmm_xt_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let (out, inp, batch) = (12, 18, 5);
        let w = sparse_random(out, inp, 0.3, &mut rng);
        let x: Vec<f32> = (0..batch * inp).map(|_| rng.next_f32()).collect();
        let csr = Csr::from_dense(&w, out, inp);
        let mut y1 = vec![0.0; batch * out];
        csr.spmm_xt(&x, &mut y1, batch);
        // reference: y[b][o] = Σ_i x[b][i] w[o][i]
        let mut y2 = vec![0.0f32; batch * out];
        for b in 0..batch {
            for o in 0..out {
                let mut acc = 0.0;
                for i in 0..inp {
                    acc += x[b * inp + i] * w[o * inp + i];
                }
                y2[b * out + o] = acc;
            }
        }
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn storage_accounting() {
        // 10% density 300×100: CSR ≈ nnz*8 + (rows+1)*4 bytes ≫ packed blocks' nnz*4
        let mut rng = Xoshiro256pp::seed_from_u64(35);
        let d = sparse_random(300, 100, 0.1, &mut rng);
        let csr = Csr::from_dense(&d, 300, 100);
        let expect = csr.nnz() * 8 + 301 * 4;
        assert_eq!(csr.storage_bytes(), expect);
        assert!(csr.storage_bytes() > csr.nnz() * 4, "CSR must carry index overhead");
    }

    #[test]
    fn empty_matrix() {
        let d = vec![0.0f32; 6];
        let csr = Csr::from_dense(&d, 2, 3);
        assert_eq!(csr.nnz(), 0);
        let mut y = vec![0.0; 2];
        csr.spmv(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![0.0, 0.0]);
    }
}
