//! A small scoped-parallelism helper for the block-diagonal GEMM.
//!
//! Each diagonal block of an MPD-packed layer is an *independent* GEMM — the
//! paper's "key enabler" (§1: "the matrix multiplication and accumulation
//! required for each block … has no dependence on any other blocks"). This
//! module exposes [`parallel_chunks`], which partitions disjoint output
//! ranges across `std::thread::scope` workers. On the single-core CI image
//! this degrades to sequential execution (nthreads=1) with zero overhead;
//! the *independence* property itself is asserted by tests regardless of
//! core count.

/// Run `f(chunk_index)` for every index in `0..nchunks`, distributed over
/// `nthreads` OS threads. `f` must only touch disjoint state per index —
/// enforced here by requiring `Fn + Sync` and passing only the index.
pub fn parallel_indices<F>(nchunks: usize, nthreads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nthreads = nthreads.max(1).min(nchunks.max(1));
    if nthreads <= 1 || nchunks <= 1 {
        for i in 0..nchunks {
            f(i);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= nchunks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Split a mutable slice into disjoint chunks at the given boundaries and run
/// `f(chunk_idx, chunk)` in parallel. Boundaries are prefix offsets
/// (`offsets[i]..offsets[i+1]` is chunk `i`).
pub fn parallel_chunks<T: Send, F>(data: &mut [T], offsets: &[usize], nthreads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(!offsets.is_empty());
    assert_eq!(*offsets.last().unwrap(), data.len(), "offsets must cover the slice");
    let nchunks = offsets.len() - 1;
    // Carve disjoint &mut chunks safely via split_at_mut chaining.
    let mut chunks: Vec<&mut [T]> = Vec::with_capacity(nchunks);
    let mut rest = data;
    let mut prev = 0usize;
    for &end in &offsets[1..] {
        assert!(end >= prev, "offsets must be non-decreasing");
        let (head, tail) = rest.split_at_mut(end - prev);
        chunks.push(head);
        rest = tail;
        prev = end;
    }
    // Hand ownership of each chunk to exactly one task index.
    let slots: Vec<std::sync::Mutex<Option<&mut [T]>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    parallel_indices(nchunks, nthreads, |i| {
        let chunk = slots[i].lock().unwrap().take().expect("chunk taken twice");
        f(i, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_indices_visits_all_once() {
        for nthreads in [1, 2, 4] {
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            parallel_indices(37, nthreads, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "nthreads={nthreads}");
        }
    }

    #[test]
    fn parallel_indices_zero_chunks() {
        parallel_indices(0, 4, |_| panic!("should not be called"));
    }

    #[test]
    fn parallel_chunks_disjoint_writes() {
        let mut data = vec![0u32; 100];
        let offsets = vec![0usize, 10, 35, 35, 80, 100]; // includes empty chunk
        for nthreads in [1, 3] {
            data.iter_mut().for_each(|v| *v = 0);
            parallel_chunks(&mut data, &offsets, nthreads, |i, chunk| {
                for v in chunk.iter_mut() {
                    *v = i as u32 + 1;
                }
            });
            for (i, w) in offsets.windows(2).enumerate() {
                for j in w[0]..w[1] {
                    assert_eq!(data[j], i as u32 + 1, "pos {j} chunk {i} nthreads {nthreads}");
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn parallel_chunks_rejects_short_offsets() {
        let mut data = vec![0u32; 10];
        parallel_chunks(&mut data, &[0, 5], 1, |_, _| {});
    }
}
