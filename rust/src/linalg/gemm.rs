//! Dense GEMM/GEMV — the baseline the paper's block-diagonal format competes
//! against, and the inner kernel each diagonal block is multiplied with.
//!
//! `C[m×n] = A[m×k] · B[k×n] (+ C)`, row-major. The hot path
//! [`gemm`] is register-blocked: the inner loop broadcasts one `A` element
//! over a contiguous `B` row and FMA-accumulates into a contiguous `C` row —
//! the classic "ikj" order that is unit-stride on both streams and
//! auto-vectorizes cleanly. A 4-row outer micro-kernel reuses each loaded
//! `B` row four times to cut B-stream traffic. Correctness is pinned to
//! [`gemm_naive`] by randomized tests.

/// Unrolled dot product — the shared inner kernel of the dot-product-form
/// GEMMs (`gemv`, `gemm_a_bt`, and the block-diagonal matmul).
/// `chunks_exact(8)` gives the compiler bounds-check-free fixed-width
/// blocks (vectorizes), and four independent accumulators break the FP-add
/// dependency chain. Arrived at through the §Perf iteration log in
/// EXPERIMENTS.md (array-indexed accumulators regressed; chunked scalar
/// accumulators won 2.3× over the original 4-wide indexed loop).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    // see doc comment: chunked, bounds-check-free, 4 accumulators
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += x[0] * y[0] + x[4] * y[4];
        acc1 += x[1] * y[1] + x[5] * y[5];
        acc2 += x[2] * y[2] + x[6] * y[6];
        acc3 += x[3] * y[3] + x[7] * y[7];
    }
    let mut s = (acc0 + acc1) + (acc2 + acc3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Naive triple loop, kept as the oracle for the optimized kernels.
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] += acc;
        }
    }
}

/// Optimized dense GEMM: `C += A·B`. Row-major, contiguous slices.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");

    // 4-row micro-kernel: for each p, broadcast a0..a3 and sweep B row p once.
    let m4 = m / 4 * 4;
    let mut i = 0;
    while i < m4 {
        let (c0s, rest) = c[i * n..].split_at_mut(n);
        let (c1s, rest) = rest.split_at_mut(n);
        let (c2s, rest) = rest.split_at_mut(n);
        let c3s = &mut rest[..n];
        for p in 0..k {
            let a0 = a[i * k + p];
            let a1 = a[(i + 1) * k + p];
            let a2 = a[(i + 2) * k + p];
            let a3 = a[(i + 3) * k + p];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue; // masked-weight matrices are mostly zero rowschunks
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                let bv = brow[j];
                c0s[j] += a0 * bv;
                c1s[j] += a1 * bv;
                c2s[j] += a2 * bv;
                c3s[j] += a3 * bv;
            }
        }
        i += 4;
    }
    // remainder rows
    for i in m4..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `y += W·x` for a row-major `W[m×k]`, `x[k]`, `y[m]` — single-sample path.
pub fn gemv(w: &[f32], x: &[f32], y: &mut [f32], m: usize, k: usize) {
    assert_eq!(w.len(), m * k);
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), m);
    for i in 0..m {
        y[i] += dot(&w[i * k..(i + 1) * k], x);
    }
}

/// `C = A·Bᵀ` convenience (used by backprop: dX = dY·W, with W row-major
/// `[out×in]` this is dY[batch×out] · W[out×in] → gemm; and
/// dW = dYᵀ·X needs the transposed-A variant below).
pub fn gemm_at_b(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    // C[m×n] += Aᵀ·B where A is [k×m], B is [k×n]
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `C += A·Bᵀ` where A is [m×k], B is [n×k] — dot-product form, used when the
/// weight matrix is stored `[out×in]` and we need `X·Wᵀ` (batch forward).
pub fn gemm_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            crow[j] += dot(arow, brow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::prng::Xoshiro256pp;

    fn randv(n: usize, rng: &mut Xoshiro256pp) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn gemm_matches_naive_over_shapes() {
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 4, 4), (17, 33, 9), (64, 100, 32), (5, 1, 8)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut c1 = randv(m * n, &mut rng);
            let mut c2 = c1.clone();
            gemm_naive(&a, &b, &mut c1, m, k, n);
            gemm(&a, &b, &mut c2, m, k, n);
            assert_close(&c1, &c2, 1e-5);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        for (m, k) in [(1, 1), (10, 7), (300, 100), (33, 65)] {
            let w = randv(m * k, &mut rng);
            let x = randv(k, &mut rng);
            let mut y1 = vec![0.0; m];
            let mut y2 = vec![0.0; m];
            gemv(&w, &x, &mut y1, m, k);
            gemm_naive(&w, &x, &mut y2, m, k, 1);
            assert_close(&y1, &y2, 1e-5);
        }
    }

    #[test]
    fn gemm_at_b_matches_explicit_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let (m, k, n) = (9, 13, 7);
        let a = randv(k * m, &mut rng); // A is k×m
        let b = randv(k * n, &mut rng);
        let mut c1 = vec![0.0; m * n];
        gemm_at_b(&a, &b, &mut c1, m, k, n);
        // explicit transpose then naive
        let mut at = vec![0.0; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&at, &b, &mut c2, m, k, n);
        assert_close(&c1, &c2, 1e-5);
    }

    #[test]
    fn gemm_a_bt_matches_explicit_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(24);
        let (m, k, n) = (6, 11, 8);
        let a = randv(m * k, &mut rng);
        let b = randv(n * k, &mut rng); // B is n×k
        let mut c1 = vec![0.0; m * n];
        gemm_a_bt(&a, &b, &mut c1, m, k, n);
        let mut bt = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                bt[p * n + j] = b[j * k + p];
            }
        }
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&a, &bt, &mut c2, m, k, n);
        assert_close(&c1, &c2, 1e-5);
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let a = vec![1.0f32, 0.0, 0.0, 1.0];
        let b = vec![5.0f32, 6.0, 7.0, 8.0];
        let mut c = vec![1.0f32; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![6.0, 7.0, 8.0, 9.0]);
    }
}
