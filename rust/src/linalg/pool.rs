//! Persistent worker pool — the execution engine under every parallel hot
//! path (block-diagonal GEMM, fused packed forward, batcher backends).
//!
//! The seed implementation (a `threadpool::parallel_indices` helper, since
//! removed) spawned fresh `std::thread::scope` workers on *every* GEMM call;
//! at serving batch sizes
//! the spawn/join cost rivals the kernel itself. This module replaces it with
//! long-lived workers that park on a condvar between jobs:
//!
//! * **Job model** — a job is "run `f(i)` for every `i in 0..nchunks`".
//!   Chunks are claimed from a shared atomic cursor, so imbalanced chunk
//!   costs (ragged MPD blocks) self-balance.
//! * **Lifecycle** — `ThreadPool::new(n)` spawns `n − 1` OS threads; the
//!   caller of [`ThreadPool::run`] is always the n-th lane, so `new(1)` is a
//!   zero-thread pool that degrades to an inline loop with zero overhead.
//!   Workers park on a condvar when idle and are woken per job; `Drop` flags
//!   shutdown and joins every worker (asserted by the leak tests).
//! * **Scoped borrows without `'static`** — `run` type-erases `&F` into a raw
//!   pointer and returns only after every claimed chunk has completed (a
//!   per-job completion count, confirmed under the job's mutex), so the
//!   closure and its borrows are provably alive whenever a worker can touch
//!   them. Workers that wake late see an exhausted cursor and never
//!   dereference the closure.
//! * **Sharing** — one process-global instance ([`global`]) serves callers
//!   that don't manage a pool themselves (sized by `MPDC_POOL_THREADS` or
//!   the available parallelism); engines that want isolation own an
//!   `Arc<ThreadPool>` ([`crate::compress::packed_model::PackedMlp::with_threads`]).
//!
//! Do **not** call `run` from inside a job closure on the same pool: jobs are
//! serialized by an internal lock and a nested call would deadlock. The
//! engine never nests (parallelism lives at the block level only).

use std::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Type-erased `&F` handed to workers. Soundness argument in [`ThreadPool::run`].
struct RawTask {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: the pointer refers to an `F: Fn(usize) + Sync` that `run` keeps
// alive (and exclusively manages) until every chunk has completed; `Sync`
// makes concurrent `&F` calls legal.
unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

/// One published unit of work: chunk cursor + completion accounting.
struct Job {
    task: RawTask,
    total: usize,
    /// Next unclaimed chunk index.
    next: AtomicUsize,
    /// Chunks whose `f(i)` call has returned (or panicked — a panicked chunk
    /// still counts, so the caller never deadlocks waiting on it).
    completed: AtomicUsize,
    /// Worker admission tickets: bounds lanes to the caller-requested cap.
    tickets: AtomicIsize,
    /// First panic payload raised inside `f`, re-raised on the caller after
    /// the job drains — matching `std::thread::scope` semantics.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
    done_lock: Mutex<bool>,
    done_cv: Condvar,
}

impl Job {
    /// Claim and run chunks until the cursor is exhausted. Panics inside the
    /// closure are caught and stashed (never unwound across a lane): the
    /// remaining chunks still run, completion still reaches `total`, and the
    /// caller re-raises the first payload — so a panicking chunk can neither
    /// leave a worker holding a dangling closure pointer nor wedge the
    /// caller's completion wait.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                break;
            }
            // SAFETY: i < total, so `run` has not returned yet and the
            // closure behind `data` is alive; see module docs.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                (self.task.call)(self.task.data, i)
            }));
            if let Err(payload) = result {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
                let mut done = self.done_lock.lock().unwrap();
                *done = true;
                self.done_cv.notify_all();
            }
        }
    }
}

/// What idle workers watch: a generation counter plus the current job.
struct Inbox {
    gen: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    inbox: Mutex<Inbox>,
    work_cv: Condvar,
}

/// A persistent pool of `lanes() - 1` worker threads plus the calling thread.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    lanes: usize,
    /// Serializes jobs: one in flight at a time; concurrent callers queue here.
    run_lock: Mutex<()>,
}

impl ThreadPool {
    /// A pool with `nthreads` total lanes (the caller counts as one, so this
    /// spawns `nthreads - 1` OS threads). `new(0)` and `new(1)` are inline.
    pub fn new(nthreads: usize) -> Self {
        let lanes = nthreads.max(1);
        let shared = Arc::new(Shared {
            inbox: Mutex::new(Inbox { gen: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
        });
        let workers = (0..lanes - 1)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mpdc-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers, lanes, run_lock: Mutex::new(()) }
    }

    /// Total parallel lanes (worker threads + the calling thread).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Spawned worker threads (`lanes() - 1`; a count of handles, not a
    /// liveness check — see [`Self::live_lanes`] for that).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Liveness probe: the peak number of lanes observed running one probe
    /// job concurrently. Unlike [`Self::worker_count`] this detects dead
    /// workers — each probe chunk holds its lane briefly (bounded at 500 ms)
    /// to let the others rendezvous, so a healthy pool reports ≥ 2 and a
    /// pool whose workers died reports 1. Used by leak/shutdown tests.
    pub fn live_lanes(&self) -> usize {
        if self.lanes <= 1 || self.workers.is_empty() {
            return 1;
        }
        let lanes = self.lanes;
        let inside = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        self.run(lanes, |_| {
            let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while inside.load(Ordering::SeqCst) < lanes
                && t0.elapsed() < std::time::Duration::from_millis(500)
            {
                std::thread::yield_now();
            }
            inside.fetch_sub(1, Ordering::SeqCst);
        });
        peak.load(Ordering::SeqCst)
    }

    /// Run `f(i)` for every `i in 0..nchunks`, distributed over the pool.
    /// Returns after every call has completed. `f` must only touch disjoint
    /// state per index (enforced by `Fn + Sync` plus index-only input).
    pub fn run<F: Fn(usize) + Sync>(&self, nchunks: usize, f: F) {
        self.run_capped(nchunks, usize::MAX, f)
    }

    /// [`ThreadPool::run`] with at most `max_lanes` lanes participating —
    /// compatibility shim for call sites that carry an explicit `nthreads`.
    pub fn run_capped<F: Fn(usize) + Sync>(&self, nchunks: usize, max_lanes: usize, f: F) {
        if nchunks == 0 {
            return;
        }
        let lanes = self.lanes.min(max_lanes).max(1);
        if lanes == 1 || nchunks == 1 || self.workers.is_empty() {
            for i in 0..nchunks {
                f(i);
            }
            return;
        }
        let _guard = self.run_lock.lock().unwrap();

        // SAFETY of the thunk: p is produced from `&f` below; `run_capped`
        // keeps f alive until every chunk completed.
        unsafe fn call_thunk<F: Fn(usize)>(p: *const (), i: usize) {
            (*(p as *const F))(i)
        }
        let job = Arc::new(Job {
            task: RawTask { data: &f as *const F as *const (), call: call_thunk::<F> },
            total: nchunks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            tickets: AtomicIsize::new((lanes - 1) as isize),
            panic: Mutex::new(None),
            done_lock: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.gen = inbox.gen.wrapping_add(1);
            inbox.job = Some(job.clone());
            // Wake only as many workers as can usefully participate —
            // notify_all would thundering-herd every parked worker on every
            // small GEMM. Workers left parked simply join the next job (the
            // gen check is an inequality), and job completion never depends
            // on any worker: the caller lane drains the cursor regardless.
            let useful = (lanes - 1).min(nchunks.saturating_sub(1));
            for _ in 0..useful {
                self.shared.work_cv.notify_one();
            }
        }
        // The caller is always a lane — it starts on chunks immediately
        // instead of sleeping until workers finish.
        job.work();
        // Wait for in-flight chunks on other lanes. `completed == total`
        // implies every `f(i)` call has returned (completion is counted
        // after the call), so the borrow of `f` ends here.
        let mut done = job.done_lock.lock().unwrap();
        while !*done {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        // Drop the pool's reference to the job so the erased pointer does not
        // linger in the inbox after `f` is gone. Workers that already cloned
        // the Arc only see an exhausted cursor.
        self.shared.inbox.lock().unwrap().job = None;
        // Re-raise a chunk panic on the caller, like thread::scope would.
        // The job is fully drained, so the pool stays usable afterwards —
        // which requires releasing run_lock BEFORE unwinding: dropping a
        // MutexGuard during a panic poisons the mutex and would wedge every
        // later run() with a PoisonError.
        let payload = job.panic.lock().unwrap().take();
        drop(_guard);
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut inbox = shared.inbox.lock().unwrap();
            loop {
                if inbox.shutdown {
                    return;
                }
                if inbox.gen != last_gen {
                    last_gen = inbox.gen;
                    break inbox.job.clone();
                }
                inbox = shared.work_cv.wait(inbox).unwrap();
            }
        };
        if let Some(job) = job {
            // Admission ticket: bounds participating lanes to the cap the
            // caller asked for. Skipping is always safe — skippers never
            // touch the closure.
            if job.tickets.fetch_sub(1, Ordering::AcqRel) > 0 {
                job.work();
            }
        }
    }
}

/// The process-global pool: sized by `MPDC_POOL_THREADS` when set, otherwise
/// by the available parallelism — on a single-core host that means 1 lane,
/// i.e. the zero-overhead inline path (tests that need real thread
/// interaction construct their own multi-lane pools). Never dropped — its
/// workers live for the process, which is the point of a persistent pool.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::env::var("MPDC_POOL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            // 0 reads as "no pool threads" → 1 lane (inline), matching the
            // minimum an operator could mean rather than silently maxing out
            .map(|n| n.max(1))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ThreadPool::new(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        for nthreads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(nthreads);
            assert_eq!(pool.lanes(), nthreads.max(1));
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.run(97, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "nthreads={nthreads}"
            );
        }
    }

    #[test]
    fn zero_chunks_is_a_noop() {
        let pool = ThreadPool::new(4);
        pool.run(0, |_| panic!("must not be called"));
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        // The whole point vs scoped threads: no spawn per call. Hammer the
        // same pool with many small jobs and check the accounting every time.
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for round in 1..200u64 {
            pool.run(round as usize % 7 + 1, |i| {
                total.fetch_add(round * 1000 + i as u64, Ordering::Relaxed);
            });
        }
        let expect: u64 = (1..200u64)
            .map(|round| {
                let n = round as usize % 7 + 1;
                (0..n as u64).map(|i| round * 1000 + i).sum::<u64>()
            })
            .sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
        assert_eq!(pool.worker_count(), 3);
    }

    #[test]
    fn run_capped_limits_lanes_but_completes() {
        let pool = ThreadPool::new(8);
        let concurrent = AtomicIsize::new(0);
        let peak = AtomicIsize::new(0);
        pool.run_capped(64, 2, |_| {
            let now = concurrent.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            concurrent.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn concurrent_callers_serialize_safely() {
        let pool = Arc::new(ThreadPool::new(4));
        let total = Arc::new(AtomicU64::new(0));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    pool.run(11, |i| {
                        total.fetch_add(i as u64 + 1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // 4 threads × 50 runs × Σ(1..=11)
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 66);
    }

    #[test]
    fn drop_joins_all_workers() {
        // If Drop failed to wake/join parked workers this test would hang
        // (caught by the harness timeout) — and the leak_test binary
        // additionally asserts on the process thread count.
        for _ in 0..20 {
            let pool = ThreadPool::new(6);
            pool.run(12, |_| {});
            drop(pool);
        }
    }

    #[test]
    fn global_pool_exists_and_runs() {
        let p = global();
        // ≥ 1 lane always; ≥ 2 only when MPDC_POOL_THREADS doesn't force 1
        assert!(p.lanes() >= 1);
        let sum = AtomicUsize::new(0);
        p.run(10, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        // A panicking chunk must neither deadlock the caller nor poison the
        // pool: the panic resurfaces on the caller (like thread::scope) and
        // the next job runs normally.
        let pool = ThreadPool::new(4);
        let ran = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, |i| {
                ran.fetch_add(1, Ordering::SeqCst);
                if i == 3 {
                    panic!("chunk 3 exploded");
                }
            });
        }));
        let err = result.expect_err("panic must propagate to the caller");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("chunk 3"), "unexpected payload {msg:?}");
        // every chunk was still claimed and attempted — no dangling work
        assert_eq!(ran.load(Ordering::SeqCst), 16);
        // pool remains fully usable, and the workers are actually alive
        // (worker_count would pass even with dead threads; live_lanes won't)
        let sum = AtomicUsize::new(0);
        pool.run(8, |i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 36);
        assert!(pool.live_lanes() >= 2, "workers died after chunk panic");
    }
}
