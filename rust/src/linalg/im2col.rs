//! im2col lowering — how convolutions reach the packed block-diagonal engine.
//!
//! A `Conv2d` with weights `[out_c, in_c, kh, kw]` is exactly a dense FC
//! layer over sliding-window patches: flatten the filters to the
//! `(out_c × in_c·kh·kw)` *filter matrix* `W`, extract every receptive field
//! of the NCHW input into a row of the *patch matrix*
//! `[batch·oh·ow × in_c·kh·kw]`, and the convolution is `Y = patches · Wᵀ` —
//! the same `X·Wᵀ` contract every FC kernel in this repo implements. That is
//! the whole trick: once lowered, a conv layer runs on the register-tiled
//! packed block-diagonal GEMM (f32 or i8) with the fused bias+ReLU epilogue,
//! MPD masks apply to the filter matrix exactly as they do to FC weight
//! matrices, and the compression/accounting machinery needs no new cases.
//!
//! ## Ordering contract (bit-exactness)
//!
//! Patch columns are ordered `(ic·kh + ky)·kw + kx` — identical to the
//! filter-matrix column order — and padded taps contribute literal `0.0`
//! entries. Because the block kernel accumulates products in ascending
//! column order starting from `+0.0` and adds the bias in the epilogue
//! (`acc + bias`), and because adding a `±0.0` product never changes an
//! accumulator that started at `+0.0`, the lowered forward is **bit-identical**
//! to the direct convolution loop in [`crate::nn::conv::Conv2d::forward`]
//! (which sums taps in the same `ic → ky → kx` order, skipping padded taps,
//! then adds the bias last). `tests/conv.rs` pins this down across tile
//! shapes and thread counts.

/// Geometry of one conv layer application: input shape + kernel + stride/pad.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub in_c: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    /// Output spatial dims (same formula as `Conv2d::out_hw`).
    pub fn out_hw(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad - self.kh) / self.stride + 1,
            (self.w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// Patch-matrix column count == filter-matrix column count.
    pub fn patch_dim(&self) -> usize {
        self.in_c * self.kh * self.kw
    }

    /// Patch-matrix rows contributed per sample.
    pub fn patches_per_sample(&self) -> usize {
        let (oh, ow) = self.out_hw();
        oh * ow
    }

    pub fn in_dim(&self) -> usize {
        self.in_c * self.h * self.w
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.in_c == 0 || self.h == 0 || self.w == 0 || self.kh == 0 || self.kw == 0 {
            return Err("conv shape has a zero dimension".into());
        }
        if self.stride == 0 {
            return Err("conv stride must be ≥ 1".into());
        }
        if self.h + 2 * self.pad < self.kh || self.w + 2 * self.pad < self.kw {
            return Err(format!(
                "kernel {}×{} does not fit padded input {}×{} (pad {})",
                self.kh, self.kw, self.h, self.w, self.pad
            ));
        }
        Ok(())
    }
}

/// Lower NCHW activations `[batch × in_c·h·w]` to the patch matrix
/// `[batch·oh·ow × patch_dim]` (row-major, reusing `out`'s allocation).
/// Row `(bi·oh + oy)·ow + ox` holds the receptive field of output pixel
/// `(oy, ox)` of sample `bi`; out-of-bounds (padded) taps are `0.0`.
///
/// The inner `kx` loop is a contiguous run in the input row (`ix` advances
/// by exactly 1 per tap), so after clipping the in-bounds `[kx_lo, kx_hi)`
/// window against the padding borders the taps move as one `copy_from_slice`
/// — byte-identical to the per-tap [`im2col_reference`] loop, which
/// `tests/simd_kernels.rs` pins across padding borders, stride tails, and
/// single-column images.
pub fn im2col(x: &[f32], batch: usize, s: &ConvShape, out: &mut Vec<f32>) {
    let _span = crate::obs::span("im2col");
    assert_eq!(x.len(), batch * s.in_dim(), "im2col input shape");
    let (oh, ow) = s.out_hw();
    let pdim = s.patch_dim();
    out.clear();
    out.resize(batch * oh * ow * pdim, 0.0);
    for bi in 0..batch {
        let xs = &x[bi * s.in_dim()..(bi + 1) * s.in_dim()];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut out[((bi * oh + oy) * ow + ox) * pdim..][..pdim];
                // in-bounds kx window: pad ≤ ox·stride + kx < w + pad
                let kx_lo = s.pad.saturating_sub(ox * s.stride);
                let kx_hi = s.kw.min((s.w + s.pad).saturating_sub(ox * s.stride));
                if kx_lo >= kx_hi {
                    continue; // fully padded column range — row stays 0.0
                }
                let ix0 = ox * s.stride + kx_lo - s.pad;
                let run = kx_hi - kx_lo;
                for ic in 0..s.in_c {
                    for ky in 0..s.kh {
                        let iy = oy * s.stride + ky;
                        if iy < s.pad || iy - s.pad >= s.h {
                            continue; // row stays 0.0 (padded)
                        }
                        let iy = iy - s.pad;
                        let xrow = &xs[(ic * s.h + iy) * s.w..][..s.w];
                        let prow = &mut row[(ic * s.kh + ky) * s.kw..][..s.kw];
                        prow[kx_lo..kx_hi].copy_from_slice(&xrow[ix0..ix0 + run]);
                    }
                }
            }
        }
    }
}

/// The seed's per-tap im2col loop, kept as the oracle the run-copy
/// [`im2col`] above is differentially tested against (byte-for-byte).
pub fn im2col_reference(x: &[f32], batch: usize, s: &ConvShape, out: &mut Vec<f32>) {
    assert_eq!(x.len(), batch * s.in_dim(), "im2col input shape");
    let (oh, ow) = s.out_hw();
    let pdim = s.patch_dim();
    out.clear();
    out.resize(batch * oh * ow * pdim, 0.0);
    for bi in 0..batch {
        let xs = &x[bi * s.in_dim()..(bi + 1) * s.in_dim()];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut out[((bi * oh + oy) * ow + ox) * pdim..][..pdim];
                for ic in 0..s.in_c {
                    for ky in 0..s.kh {
                        let iy = oy * s.stride + ky;
                        if iy < s.pad || iy - s.pad >= s.h {
                            continue; // row stays 0.0 (padded)
                        }
                        let iy = iy - s.pad;
                        let xrow = &xs[(ic * s.h + iy) * s.w..][..s.w];
                        let prow = &mut row[(ic * s.kh + ky) * s.kw..][..s.kw];
                        for kx in 0..s.kw {
                            let ix = ox * s.stride + kx;
                            if ix < s.pad || ix - s.pad >= s.w {
                                continue;
                            }
                            prow[kx] = xrow[ix - s.pad];
                        }
                    }
                }
            }
        }
    }
}

/// Column-gather every row of a `[nrows × dim]` matrix into `out`:
/// `out[r][j] = rows[r][gather[j]]` — how a masked conv stage moves patch
/// columns into `P_col` (block) space before the packed GEMM. Shared by the
/// f32 and i8 conv engines so the gather semantics cannot drift.
pub fn gather_cols(rows: &[f32], nrows: usize, dim: usize, gather: &[u32], out: &mut Vec<f32>) {
    gather_cols_isa(rows, nrows, dim, gather, out, crate::linalg::kernel::Isa::Scalar);
}

/// [`gather_cols`] with an explicit kernel ISA — the entry the executor
/// dispatches through. A gather moves bits without rounding, so every ISA
/// is byte-identical; the AVX2 form uses `vgatherdps` eight columns at a
/// time. Index bounds are asserted **once up front** (the SIMD gather has no
/// per-lane bounds check, unlike the scalar indexing).
pub fn gather_cols_isa(
    rows: &[f32],
    nrows: usize,
    dim: usize,
    gather: &[u32],
    out: &mut Vec<f32>,
    isa: crate::linalg::kernel::Isa,
) {
    assert_eq!(rows.len(), nrows * dim, "gather input shape");
    assert_eq!(gather.len(), dim, "gather length");
    assert!(gather.iter().all(|&s| (s as usize) < dim), "gather index out of range");
    out.resize(rows.len(), 0.0);
    for r in 0..nrows {
        let src = &rows[r * dim..(r + 1) * dim];
        let dst = &mut out[r * dim..(r + 1) * dim];
        crate::linalg::kernel::gather_row_f32(isa, src, gather, dst);
    }
}

/// Transpose the GEMM output `[batch·oh·ow × out_c]` back to NCHW
/// `[batch × out_c·oh·ow]`, optionally restoring logical channel order:
/// when `chan_src` is given, logical channel `oc` pulls from GEMM column
/// `chan_src[oc]` (the block-row-space column the packed kernel wrote it to).
pub fn rows_to_nchw(
    rows: &[f32],
    batch: usize,
    out_c: usize,
    oh: usize,
    ow: usize,
    chan_src: Option<&[u32]>,
    out: &mut Vec<f32>,
) {
    assert_eq!(rows.len(), batch * oh * ow * out_c, "rows shape");
    if let Some(g) = chan_src {
        assert_eq!(g.len(), out_c, "channel gather length");
    }
    out.clear();
    out.resize(rows.len(), 0.0);
    for bi in 0..batch {
        for oc in 0..out_c {
            let src_c = match chan_src {
                Some(g) => g[oc] as usize,
                None => oc,
            };
            let dst = &mut out[((bi * out_c + oc) * oh * ow)..][..oh * ow];
            for (p, d) in dst.iter_mut().enumerate() {
                *d = rows[((bi * oh * ow) + p) * out_c + src_c];
            }
        }
    }
}

/// Stateless NCHW max-pool (inference path; the trainable
/// [`crate::nn::conv::MaxPool2d`] additionally caches argmax for backward).
/// Identical tie-breaking (`>` keeps the first maximum), so the value stream
/// matches the trainer's pooling bit-for-bit.
pub fn maxpool_nchw(
    x: &[f32],
    batch: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(x.len(), batch * c * h * w, "maxpool input shape");
    assert!(k >= 1 && stride >= 1 && h >= k && w >= k, "maxpool geometry");
    let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
    out.clear();
    out.resize(batch * c * oh * ow, 0.0);
    for bc in 0..batch * c {
        let xp = &x[bc * h * w..(bc + 1) * h * w];
        let yp = &mut out[bc * oh * ow..(bc + 1) * oh * ow];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f32::NEG_INFINITY;
                for ky in 0..k {
                    for kx in 0..k {
                        let v = xp[(oy * stride + ky) * w + (ox * stride + kx)];
                        if v > best {
                            best = v;
                        }
                    }
                }
                yp[oy * ow + ox] = best;
            }
        }
    }
}

/// Stateless NCHW average-pool (global average pooling is the `k == h == w`
/// special case, producing one value per channel). Each window accumulates
/// taps in ascending `ky → kx` order starting from `+0.0`, then divides by
/// `k·k` as an f32 (exactly representable for any practical window) — the
/// trainable [`crate::nn::conv::AvgPool2d`] uses the identical accumulation
/// order and divisor, so the value stream matches the trainer bit-for-bit.
pub fn avgpool_nchw(
    x: &[f32],
    batch: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(x.len(), batch * c * h * w, "avgpool input shape");
    assert!(k >= 1 && stride >= 1 && h >= k && w >= k, "avgpool geometry");
    let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
    let area = (k * k) as f32;
    out.clear();
    out.resize(batch * c * oh * ow, 0.0);
    for bc in 0..batch * c {
        let xp = &x[bc * h * w..(bc + 1) * h * w];
        let yp = &mut out[bc * oh * ow..(bc + 1) * oh * ow];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0.0f32;
                for ky in 0..k {
                    for kx in 0..k {
                        acc += xp[(oy * stride + ky) * w + (ox * stride + kx)];
                    }
                }
                yp[oy * ow + ox] = acc / area;
            }
        }
    }
}

/// One GEMM A-matrix column of a fused conv stage, pre-resolved to its
/// input tap: reading column `j` of patch row `(oy, ox)` means reading the
/// NCHW sample at `chan_off + (oy·stride + ky − pad)·w + (ox·stride + kx − pad)`
/// — or a literal zero when that tap falls in the padding border. The
/// decomposition (`P_col` gather included) happens once at fuse time, so the
/// packing loop does two adds and two compares per tap instead of a whole
/// materialized patch-matrix pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatchTap {
    /// `ic·h·w` — channel base offset into the sample's NCHW buffer.
    pub chan_off: u32,
    pub ky: u16,
    pub kx: u16,
}

/// Resolve every GEMM column of a fused conv stage to its [`PatchTap`].
/// `col_gather` is the conv `P_col` patch gather (`None` = identity):
/// GEMM column `j` reads patch column `col_gather[j]`, which decomposes by
/// the im2col ordering contract `(ic·kh + ky)·kw + kx`.
pub fn patch_taps(s: &ConvShape, col_gather: Option<&[u32]>) -> Vec<PatchTap> {
    let pdim = s.patch_dim();
    if let Some(g) = col_gather {
        assert_eq!(g.len(), pdim, "patch gather length");
        assert!(g.iter().all(|&c| (c as usize) < pdim), "patch gather index out of range");
    }
    (0..pdim)
        .map(|j| {
            let src = col_gather.map_or(j, |g| g[j] as usize);
            let ic = src / (s.kh * s.kw);
            let rem = src % (s.kh * s.kw);
            PatchTap {
                chan_off: (ic * s.h * s.w) as u32,
                ky: (rem / s.kw) as u16,
                kx: (rem % s.kw) as u16,
            }
        })
        .collect()
}

/// Where a fused GEMM's A-panel rows come from: the packing loop of the
/// fused block kernels reads *source* activations through this descriptor
/// instead of a materialized patch/gathered matrix in the arena.
///
/// The packed values are defined to be byte-identical to what the unfused
/// pipeline would have materialized (`im2col` + `gather_cols` for conv,
/// `gather_cols` for FC): padded taps pack literal `0.0` (or quantized 0,
/// which `quantize_i8(0.0)` also yields), so the downstream accumulation
/// sees the same operand stream in the same order — the fused-≡-unfused
/// bit-exactness argument (DESIGN.md §Fusion) reduces to this equality.
pub enum PanelSource<'a> {
    /// Implicit im2col: row `gr` is output pixel `(gr / ow) % oh, gr % ow`
    /// of sample `gr / (oh·ow)`; column `j` resolves through `taps[j]`.
    Im2col { shape: &'a ConvShape, taps: &'a [PatchTap] },
    /// Column gather: row `gr` is source row `gr`; column `j` reads
    /// `src[gr·src_dim + idx[j]]`.
    Gather { idx: &'a [u32], src_dim: usize },
}

impl PanelSource<'_> {
    /// Source-activation elements per A-matrix row block: im2col rows share
    /// one sample (`in_dim` per `patches_per_sample` rows), gather rows own
    /// `src_dim` each. Used by the fused kernels to validate `x` length
    /// against the caller-supplied row count.
    pub fn src_elems_for(&self, nrows: usize) -> usize {
        match self {
            PanelSource::Im2col { shape, .. } => {
                let pps = shape.patches_per_sample();
                assert_eq!(nrows % pps, 0, "im2col panel rows must cover whole samples");
                (nrows / pps) * shape.in_dim()
            }
            PanelSource::Gather { src_dim, .. } => nrows * src_dim,
        }
    }

    /// Total A-matrix columns (must equal the GEMM layout's `cols`).
    pub fn ncols(&self) -> usize {
        match self {
            PanelSource::Im2col { taps, .. } => taps.len(),
            PanelSource::Gather { idx, .. } => idx.len(),
        }
    }

    /// Pack columns `[col0, col0 + dst.len())` of A-matrix row `gr` into
    /// `dst`. `Default::default()` is the padded-tap element (`0.0` / `0i8`).
    #[inline]
    pub fn pack_row<T: Copy + Default>(&self, x: &[T], gr: usize, col0: usize, dst: &mut [T]) {
        match self {
            PanelSource::Im2col { shape, taps } => {
                let s = **shape;
                let (oh, ow) = s.out_hw();
                let pps = oh * ow;
                let xs = &x[(gr / pps) * s.in_dim()..][..s.in_dim()];
                let rem = gr % pps;
                let (oy, ox) = (rem / ow, rem % ow);
                for (d, t) in dst.iter_mut().zip(&taps[col0..col0 + dst.len()]) {
                    let iy = oy * s.stride + t.ky as usize;
                    let ix = ox * s.stride + t.kx as usize;
                    *d = if iy >= s.pad && iy - s.pad < s.h && ix >= s.pad && ix - s.pad < s.w {
                        xs[t.chan_off as usize + (iy - s.pad) * s.w + (ix - s.pad)]
                    } else {
                        T::default()
                    };
                }
            }
            PanelSource::Gather { idx, src_dim } => {
                let src = &x[gr * src_dim..][..*src_dim];
                for (d, &c) in dst.iter_mut().zip(&idx[col0..col0 + dst.len()]) {
                    *d = src[c as usize];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_a_bt;
    use crate::mask::prng::Xoshiro256pp;
    use crate::nn::conv::Conv2d;

    #[test]
    fn shapes_and_validation() {
        let s = ConvShape { in_c: 3, h: 28, w: 28, kh: 5, kw: 5, stride: 1, pad: 2 };
        assert_eq!(s.out_hw(), (28, 28));
        assert_eq!(s.patch_dim(), 75);
        s.validate().unwrap();
        let too_big = ConvShape { h: 4, w: 4, kh: 9, kw: 9, pad: 1, ..s };
        assert!(too_big.validate().is_err());
        assert!(ConvShape { stride: 0, ..s }.validate().is_err());
    }

    #[test]
    fn im2col_known_values() {
        // 1×3×3 input, 2×2 kernel, stride 1, no pad → 4 patches of 4 taps.
        let s = ConvShape { in_c: 1, h: 3, w: 3, kh: 2, kw: 2, stride: 1, pad: 0 };
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut p = Vec::new();
        im2col(&x, 1, &s, &mut p);
        assert_eq!(p.len(), 4 * 4);
        assert_eq!(&p[0..4], &[1.0, 2.0, 4.0, 5.0]); // top-left patch
        assert_eq!(&p[12..16], &[5.0, 6.0, 8.0, 9.0]); // bottom-right patch
    }

    #[test]
    fn im2col_padding_zeroes() {
        let s = ConvShape { in_c: 1, h: 2, w: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut p = Vec::new();
        im2col(&x, 1, &s, &mut p);
        // output is 2×2; the (0,0) patch sees the input in its lower-right 2×2
        assert_eq!(&p[0..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn lowered_gemm_matches_direct_conv() {
        let mut rng = Xoshiro256pp::seed_from_u64(91);
        for (in_c, h, w, out_c, k, stride, pad, batch) in
            [(1, 6, 6, 3, 3, 1, 1, 2), (2, 7, 5, 4, 3, 2, 0, 1), (3, 8, 8, 2, 5, 1, 2, 3)]
        {
            let mut conv = Conv2d::new(out_c, in_c, k, stride, pad, &mut rng);
            for b in conv.b.iter_mut() {
                *b = rng.next_f32() - 0.5;
            }
            let x: Vec<f32> = (0..batch * in_c * h * w).map(|_| rng.next_f32() - 0.5).collect();
            let direct = conv.forward(&x, batch, h, w);

            let s = ConvShape { in_c, h, w, kh: k, kw: k, stride, pad };
            let (oh, ow) = s.out_hw();
            let mut patches = Vec::new();
            im2col(&x, batch, &s, &mut patches);
            let rows = batch * oh * ow;
            let mut y = vec![0.0f32; rows * out_c];
            for r in 0..rows {
                y[r * out_c..(r + 1) * out_c].copy_from_slice(&conv.b);
            }
            gemm_a_bt(&patches, &conv.w, &mut y, rows, s.patch_dim(), out_c);
            let mut nchw = Vec::new();
            rows_to_nchw(&y, batch, out_c, oh, ow, None, &mut nchw);
            for (a, b) in nchw.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn rows_to_nchw_restores_channel_order() {
        // 1 sample, 2×1 spatial, 3 channels; gather reverses channel order.
        let rows = [0.0f32, 1.0, 2.0, 10.0, 11.0, 12.0]; // [2 rows × 3 ch]
        let mut out = Vec::new();
        rows_to_nchw(&rows, 1, 3, 2, 1, Some(&[2, 1, 0]), &mut out);
        assert_eq!(out, vec![2.0, 12.0, 1.0, 11.0, 0.0, 10.0]);
    }

    #[test]
    fn avgpool_matches_trainable_pool() {
        use crate::nn::conv::AvgPool2d;
        let mut rng = Xoshiro256pp::seed_from_u64(93);
        let (batch, c, h, w) = (2, 3, 6, 6);
        let x: Vec<f32> = (0..batch * c * h * w).map(|_| rng.next_f32() - 0.5).collect();
        let mut ap = AvgPool2d::new(2, 2);
        let want = ap.forward(&x, batch, c, h, w);
        let mut got = Vec::new();
        avgpool_nchw(&x, batch, c, h, w, 2, 2, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn avgpool_global_reduces_to_channel_means() {
        // Global pooling (k == h == w, stride irrelevant) → one value/channel.
        let x = [1.0f32, 3.0, 5.0, 7.0, /* ch1 */ 2.0, 2.0, 2.0, 2.0];
        let mut got = Vec::new();
        avgpool_nchw(&x, 1, 2, 2, 2, 2, 1, &mut got);
        assert_eq!(got, vec![4.0, 2.0]);
    }

    #[test]
    fn maxpool_matches_trainable_pool() {
        use crate::nn::conv::MaxPool2d;
        let mut rng = Xoshiro256pp::seed_from_u64(92);
        let (batch, c, h, w) = (2, 3, 6, 6);
        let x: Vec<f32> = (0..batch * c * h * w).map(|_| rng.next_f32() - 0.5).collect();
        let mut mp = MaxPool2d::new(2, 2);
        let want = mp.forward(&x, batch, c, h, w);
        let mut got = Vec::new();
        maxpool_nchw(&x, batch, c, h, w, 2, 2, &mut got);
        assert_eq!(got, want);
    }

    /// The fused-kernel equality argument bottoms out here: a packed panel
    /// row must be byte-identical to the corresponding row slice of the
    /// materialized `im2col` (+ optional column gather) pipeline, including
    /// padded taps, stride tails, and arbitrary sub-column windows.
    #[test]
    fn panel_source_packs_identical_bytes_to_materialized_pipeline() {
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        for (in_c, h, w, k, stride, pad, batch) in
            [(1, 5, 5, 3, 1, 1, 2), (2, 7, 4, 3, 2, 0, 1), (3, 6, 6, 5, 1, 2, 2), (2, 4, 4, 4, 2, 2, 3)]
        {
            let s = ConvShape { in_c, h, w, kh: k, kw: k, stride, pad };
            let pdim = s.patch_dim();
            let x: Vec<f32> = (0..batch * s.in_dim()).map(|_| rng.next_f32() - 0.5).collect();
            let mut patches = Vec::new();
            im2col(&x, batch, &s, &mut patches);
            // a pseudo-random permutation as the P_col stand-in
            let mut g: Vec<u32> = (0..pdim as u32).collect();
            for j in (1..pdim).rev() {
                g.swap(j, (rng.next_f32() * (j + 1) as f32) as usize % (j + 1));
            }
            let nrows = batch * s.patches_per_sample();
            let mut gathered = Vec::new();
            gather_cols(&patches, nrows, pdim, &g, &mut gathered);

            for (gather, want_rows) in [(None, &patches), (Some(g.as_slice()), &gathered)] {
                let taps = patch_taps(&s, gather);
                let src = PanelSource::Im2col { shape: &s, taps: &taps };
                assert_eq!(src.ncols(), pdim);
                assert_eq!(src.src_elems_for(nrows), x.len());
                for gr in 0..nrows {
                    // whole row and an awkward sub-window
                    let mut row = vec![9.0f32; pdim];
                    src.pack_row(&x, gr, 0, &mut row);
                    assert_eq!(row, want_rows[gr * pdim..(gr + 1) * pdim], "row {gr}");
                    if pdim > 3 {
                        let (c0, n) = (1, pdim - 3);
                        let mut win = vec![9.0f32; n];
                        src.pack_row(&x, gr, c0, &mut win);
                        assert_eq!(win, want_rows[gr * pdim + c0..gr * pdim + c0 + n]);
                    }
                }
            }
        }
    }

    /// Gather-sourced panel rows must match `gather_cols` byte-for-byte,
    /// f32 and i8 alike (the i8 case is how fused quantized GEMMs pack).
    #[test]
    fn panel_source_gather_matches_gather_cols() {
        let mut rng = Xoshiro256pp::seed_from_u64(78);
        let (nrows, dim) = (5, 23);
        let x: Vec<f32> = (0..nrows * dim).map(|_| rng.next_f32() - 0.5).collect();
        let idx: Vec<u32> = (0..dim).map(|j| ((j * 7 + 3) % dim) as u32).collect();
        let mut want = Vec::new();
        gather_cols(&x, nrows, dim, &idx, &mut want);
        let src = PanelSource::Gather { idx: &idx, src_dim: dim };
        for gr in 0..nrows {
            let mut row = vec![0.0f32; dim];
            src.pack_row(&x, gr, 0, &mut row);
            assert_eq!(row, want[gr * dim..(gr + 1) * dim]);
        }
        // i8: quantize-then-gather must equal gather-then-quantize
        let xq: Vec<i8> = x.iter().map(|&v| crate::linalg::blockdiag_mm_i8::quantize_i8(v, 0.01)).collect();
        let wantq: Vec<i8> = want.iter().map(|&v| crate::linalg::blockdiag_mm_i8::quantize_i8(v, 0.01)).collect();
        for gr in 0..nrows {
            let mut row = vec![0i8; dim];
            src.pack_row(&xq, gr, 0, &mut row);
            assert_eq!(row, wantq[gr * dim..(gr + 1) * dim]);
        }
    }
}
