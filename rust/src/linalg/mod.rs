//! Native linear algebra: dense GEMM, CSR (irregular-sparsity baseline), the
//! persistent worker pool, and the register-tiled packed block-diagonal GEMM
//! hot path.
pub mod blockdiag_mm;
pub mod csr;
pub mod gemm;
pub mod pool;
pub mod tensor;

pub use blockdiag_mm::{BlockDiagMatrix, TileShape};
pub use csr::Csr;
pub use pool::ThreadPool;
pub use tensor::{Matrix, Tensor};
