//! Native linear algebra: dense GEMM, CSR (irregular-sparsity baseline), the
//! persistent worker pool, and the register-tiled packed block-diagonal GEMM
//! hot paths — f32 (`blockdiag_mm`) and int8 with a fused dequantize
//! epilogue (`blockdiag_mm_i8`).
pub mod blockdiag_mm;
pub mod blockdiag_mm_i8;
pub mod csr;
pub mod gemm;
pub mod im2col;
pub mod kernel;
pub mod pool;
pub mod tensor;

pub use blockdiag_mm::{BlockDiagMatrix, TileShape};
pub use kernel::{Isa, KernelChoice};
pub use im2col::ConvShape;
pub use blockdiag_mm_i8::QuantizedBlockDiagMatrix;
pub use csr::Csr;
pub use pool::ThreadPool;
pub use tensor::{Matrix, Tensor};
