//! Native linear algebra: dense GEMM, CSR (irregular-sparsity baseline), and
//! the packed block-diagonal GEMM hot path.
pub mod blockdiag_mm;
pub mod csr;
pub mod gemm;
pub mod tensor;
pub mod threadpool;

pub use blockdiag_mm::BlockDiagMatrix;
pub use csr::Csr;
pub use tensor::{Matrix, Tensor};
