//! Packed block-diagonal GEMM — MPDCompress's inference hot path (L3 native
//! engine mirror of the L1 Pallas kernel).
//!
//! After eq. 2 re-blocking, an FC layer's weight matrix is exactly
//! block-diagonal: `k` independent dense blocks `W_b` of shape
//! `(out_b × in_b)`. We store only the blocks (`nnz` floats — the 10×
//! compression), and compute
//!
//! ```text
//!   Y[:, rows_b] = X[:, cols_b] · W_bᵀ          for each block b
//! ```
//!
//! with activations row-major `[batch × features]`. Each block touches a
//! disjoint slice of `Y`'s columns, so blocks parallelize with no
//! synchronization — the paper's "key enabler" (§1). No index arrays, no
//! gathers: contrast with `csr.rs`.

use crate::linalg::threadpool::parallel_indices;
use crate::mask::blockdiag::BlockDiagLayout;
use crate::mask::mask::MpdMask;

/// A block-diagonal weight matrix in packed storage.
///
/// Semantics: represents `W` of shape `[rows=out × cols=in]` where block `b`
/// occupies `layout.row_spans[b] × layout.col_spans[b]`; everything else is
/// structurally zero (not stored).
#[derive(Clone, Debug)]
pub struct BlockDiagMatrix {
    pub layout: BlockDiagLayout,
    /// Concatenated row-major blocks; block `b` starts at `block_off[b]` and
    /// has `row_spans[b].len * col_spans[b].len` elements.
    pub packed: Vec<f32>,
    pub block_off: Vec<usize>,
}

impl BlockDiagMatrix {
    /// Pack a dense block-diagonal matrix (e.g. the output of
    /// [`MpdMask::unpermute`]). Off-block entries must be zero — checked in
    /// debug builds.
    pub fn from_dense(data: &[f32], layout: &BlockDiagLayout) -> Self {
        debug_assert_eq!(
            crate::mask::blockdiag::off_block_mass(data, layout),
            0.0,
            "matrix is not block-diagonal under this layout"
        );
        let packed = crate::mask::blockdiag::pack_blocks(data, layout);
        Self::from_packed(packed, layout.clone())
    }

    /// Build directly from packed block storage.
    pub fn from_packed(packed: Vec<f32>, layout: BlockDiagLayout) -> Self {
        assert_eq!(packed.len(), layout.nnz());
        let mut block_off = Vec::with_capacity(layout.nblocks() + 1);
        let mut off = 0;
        for b in 0..layout.nblocks() {
            block_off.push(off);
            off += layout.row_spans[b].len * layout.col_spans[b].len;
        }
        block_off.push(off);
        Self { layout, packed, block_off }
    }

    /// One-step pack from a trained masked weight matrix: applies eq. 2
    /// (`W* = P_rowᵀ W̄ P_colᵀ`) then extracts blocks.
    pub fn from_masked_weights(mask: &MpdMask, w_masked: &[f32]) -> Self {
        Self::from_packed(mask.pack(w_masked), mask.layout.clone())
    }

    pub fn nblocks(&self) -> usize {
        self.layout.nblocks()
    }

    /// Stored parameter count (the compressed size).
    pub fn nnz(&self) -> usize {
        self.packed.len()
    }

    /// Bytes of the packed representation: values only, plus one span pair
    /// per block (the entire "index" cost of the format — contrast CSR).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() * 4 + self.layout.nblocks() * 4 * std::mem::size_of::<u32>()
    }

    /// Block `b` as a row-major `(out_b × in_b)` slice.
    #[inline]
    pub fn block(&self, b: usize) -> &[f32] {
        &self.packed[self.block_off[b]..self.block_off[b + 1]]
    }

    /// Expand back to the dense `[rows × cols]` matrix (test/debug helper).
    pub fn to_dense(&self) -> Vec<f32> {
        crate::mask::blockdiag::unpack_blocks(&self.packed, &self.layout)
    }

    /// `Y += X · Wᵀ` with `X: [batch × cols]`, `Y: [batch × rows]`,
    /// both row-major. Sequential over blocks.
    pub fn matmul_xt(&self, x: &[f32], y: &mut [f32], batch: usize) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols, "X shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        for b in 0..self.nblocks() {
            self.block_matmul(b, x, y, batch);
        }
    }

    /// Parallel-over-blocks variant. Blocks write disjoint column spans of
    /// `Y`, so per-block tasks are data-race-free; we hand out the shared
    /// buffer through a Send pointer wrapper scoped to this call.
    pub fn matmul_xt_parallel(&self, x: &[f32], y: &mut [f32], batch: usize, nthreads: usize) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols);
        assert_eq!(y.len(), batch * rows);
        if nthreads <= 1 {
            return self.matmul_xt(x, y, batch);
        }
        struct SendPtr(*mut f32, usize);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let yp = SendPtr(y.as_mut_ptr(), y.len());
        let yp = &yp; // capture the Sync wrapper, not the raw pointer field
        parallel_indices(self.nblocks(), nthreads, |b| {
            // SAFETY: block b writes only Y[:, row_spans[b]] — column spans
            // are disjoint across blocks, so no two tasks alias an element.
            let y = unsafe { std::slice::from_raw_parts_mut(yp.0, yp.1) };
            self.block_matmul(b, x, y, batch);
        });
    }

    /// The per-block micro-GEMM: `Y[:, rs] += X[:, cs] · W_bᵀ`.
    #[inline]
    fn block_matmul(&self, b: usize, x: &[f32], y: &mut [f32], batch: usize) {
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        let wb = self.block(b); // (rs.len × cs.len), row-major
        for bi in 0..batch {
            let xrow = &x[bi * cols + cs.start..bi * cols + cs.end()];
            let yrow = &mut y[bi * rows + rs.start..bi * rows + rs.end()];
            for (r, yv) in yrow.iter_mut().enumerate() {
                *yv += crate::linalg::gemm::dot(&wb[r * cs.len..(r + 1) * cs.len], xrow);
            }
        }
    }

    /// Single-sample `y += W·x` (serving fast path, batch=1 without the
    /// batch-loop overhead).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        self.matmul_xt(x, y, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_a_bt;
    use crate::mask::prng::Xoshiro256pp;

    fn mk(rows: usize, cols: usize, k: usize, rng: &mut Xoshiro256pp) -> (BlockDiagMatrix, Vec<f32>) {
        // random dense block-diagonal matrix + its dense form
        let layout = BlockDiagLayout::new(rows, cols, k);
        let mut dense = vec![0.0f32; rows * cols];
        for (b, rs) in layout.row_spans.iter().enumerate() {
            let cs = layout.col_spans[b];
            for r in rs.start..rs.end() {
                for c in cs.start..cs.end() {
                    dense[r * cols + c] = rng.next_f32() * 2.0 - 1.0;
                }
            }
        }
        (BlockDiagMatrix::from_dense(&dense, &layout), dense)
    }

    #[test]
    fn matmul_matches_dense_gemm() {
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        for (rows, cols, k, batch) in [(10, 8, 2, 1), (300, 100, 10, 4), (33, 44, 11, 7), (16, 16, 16, 3)] {
            let (bd, dense) = mk(rows, cols, k, &mut rng);
            let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32()).collect();
            let mut y1 = vec![0.0f32; batch * rows];
            bd.matmul_xt(&x, &mut y1, batch);
            let mut y2 = vec![0.0f32; batch * rows];
            gemm_a_bt(&x, &dense, &mut y2, batch, cols, rows);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4, "{rows}x{cols} k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let (bd, _) = mk(120, 90, 6, &mut rng);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 90).map(|_| rng.next_f32()).collect();
        let mut y_seq = vec![0.0f32; batch * 120];
        bd.matmul_xt(&x, &mut y_seq, batch);
        for nthreads in [2, 3, 8] {
            let mut y_par = vec![0.0f32; batch * 120];
            bd.matmul_xt_parallel(&x, &mut y_par, batch, nthreads);
            assert_eq!(y_seq, y_par, "nthreads={nthreads}");
        }
    }

    #[test]
    fn from_masked_weights_equals_masked_dense_product() {
        // end-to-end eq.-2 path: y from packed blocks on permuted input ==
        // y from the masked dense matrix on raw input, modulo permutations.
        let mut rng = Xoshiro256pp::seed_from_u64(43);
        let (rows, cols, k, batch) = (30, 20, 5, 3);
        let mask = MpdMask::generate(rows, cols, k, &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() - 0.5).collect();
        let w_masked = mask.apply(&w);
        let bd = BlockDiagMatrix::from_masked_weights(&mask, &w_masked);

        let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32()).collect();
        // reference: y = x · W̄ᵀ
        let mut y_ref = vec![0.0f32; batch * rows];
        gemm_a_bt(&x, &w_masked, &mut y_ref, batch, cols, rows);

        // packed path: x' = P_col⁻¹ x per sample; y' = blockdiag(x'); y = P_row y'
        // (x_{P_col} in the paper is P_col(d_i)·x — with our forward-map
        // convention W* = unpermute(W̄) has W*[r'][c'] = W̄[p_row(r')][p_col(c')],
        // so x'[c'] must equal x[p_col(c')], i.e. x' = p_col⁻¹ applied... use
        // apply_vec of inverse: x'[inv.dest(c)] = x[c] with inv = p_col⁻¹ means
        // x'[c'] = x[p_col(c')]. Check: inv.dest(c) = c' where p_col.dest(c') = c.
        let p_col_inv = mask.p_col.inverse();
        let p_row_inv = mask.p_row.inverse();
        let mut y_packed = vec![0.0f32; batch * rows];
        for bi in 0..batch {
            let xs = &x[bi * cols..(bi + 1) * cols];
            let xp = p_col_inv.apply_vec(xs);
            let mut yp = vec![0.0f32; rows];
            bd.matvec(&xp, &mut yp);
            // yp is in permuted (block) space: yp[r'] = y[p_row(r')] ⇒ y = apply p_row…
            let yo = p_row_inv.inverse().apply_vec(&yp); // p_row applied: y[p_row.dest? ]
            // p_row_inv.inverse() == p_row; apply_vec: y[p_row.dest(r')] = yp[r']  ✓
            y_packed[bi * rows..(bi + 1) * rows].copy_from_slice(&yo);
        }
        for (a, b) in y_packed.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_compressed() {
        let mut rng = Xoshiro256pp::seed_from_u64(44);
        let (bd, _) = mk(300, 100, 10, &mut rng);
        assert_eq!(bd.nnz(), 3000);
        assert!(bd.storage_bytes() < 300 * 100 * 4 / 9, "≥9× byte compression expected");
    }

    #[test]
    fn to_dense_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(45);
        let (bd, dense) = mk(24, 36, 4, &mut rng);
        assert_eq!(bd.to_dense(), dense);
    }
}
