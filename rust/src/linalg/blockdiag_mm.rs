//! Packed block-diagonal GEMM — MPDCompress's inference hot path (L3 native
//! engine mirror of the L1 Pallas kernel).
//!
//! After eq. 2 re-blocking, an FC layer's weight matrix is exactly
//! block-diagonal: `k` independent dense blocks `W_b` of shape
//! `(out_b × in_b)`. We store only the blocks (`nnz` floats — the 10×
//! compression), and compute
//!
//! ```text
//!   Y[:, rows_b] = X[:, cols_b] · W_bᵀ          for each block b
//! ```
//!
//! with activations row-major `[batch × features]`. Each block touches a
//! disjoint slice of `Y`'s columns, so blocks parallelize with no
//! synchronization — the paper's "key enabler" (§1). No index arrays, no
//! gathers: contrast with `csr.rs`.
//!
//! ```
//! use mpdc::linalg::blockdiag_mm::BlockDiagMatrix;
//! use mpdc::mask::mask::MpdMask;
//! use mpdc::mask::prng::Xoshiro256pp;
//!
//! // a 6×6 MPD mask with 2 blocks; mask random weights, then re-block (eq. 2)
//! let mut rng = Xoshiro256pp::seed_from_u64(1);
//! let mask = MpdMask::generate(6, 6, 2, &mut rng);
//! let w: Vec<f32> = (0..36).map(|i| i as f32 * 0.1).collect();
//! let bd = BlockDiagMatrix::from_masked_weights(&mask, &mask.apply(&w));
//! assert_eq!(bd.nnz(), mask.nnz()); // only block entries are stored
//!
//! // Y += X · Wᵀ over the packed blocks — and it is bit-identical to the
//! // scalar reference kernel (canonical accumulation order)
//! let x = vec![1.0f32; 6];
//! let (mut y, mut y_ref) = (vec![0.0f32; 6], vec![0.0f32; 6]);
//! bd.matmul_xt(&x, &mut y, 1);
//! bd.matmul_xt_reference(&x, &mut y_ref, 1);
//! assert_eq!(y, y_ref);
//! ```
//!
//! ## Kernel design (see DESIGN.md §Engine)
//!
//! The per-block kernel is a cache-blocked, register-tiled micro-GEMM: a
//! `TM × TN` accumulator tile (default 4 batch rows × 8 output rows) is held
//! in registers while the reduction dimension is swept once, so each loaded
//! `x` value is reused `TN` times and each loaded `w` value `TM` times.
//! Remainder batch/output rows fall back to a scalar path that accumulates
//! in the **same `p`-ascending order** as the tiles, so every output element
//! has one canonical value regardless of batch size, tile shape, or thread
//! count — the property the equivalence tests pin down with exact equality.
//!
//! Bias-add + ReLU fuse into the tile epilogue ([`BlockDiagMatrix::forward_fused`]):
//! the packed forward writes each activation exactly once instead of
//! bias-copy → accumulate → separate ReLU sweep.
//!
//! Parallel execution goes through the persistent [`crate::linalg::pool`]
//! (blocks are the work unit), not per-call scoped threads.

use crate::linalg::pool::ThreadPool;
use crate::mask::blockdiag::BlockDiagLayout;
use crate::mask::mask::MpdMask;

/// Register-tile shape of the micro-kernel: `batch` activation rows ×
/// `rows` block-output rows per tile. Exposed through
/// [`crate::config::EngineConfig`]; both axes must be one of {1, 2, 4, 8}.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileShape {
    pub batch: usize,
    pub rows: usize,
}

impl TileShape {
    pub const DEFAULT: TileShape = TileShape { batch: 4, rows: 8 };

    pub fn validate(&self) -> Result<(), String> {
        const OK: [usize; 4] = [1, 2, 4, 8];
        if OK.contains(&self.batch) && OK.contains(&self.rows) {
            Ok(())
        } else {
            Err(format!(
                "tile shape {}x{} unsupported: each axis must be one of 1/2/4/8",
                self.batch, self.rows
            ))
        }
    }
}

/// A-rows packed per panel pass in the fused implicit-GEMM path — the
/// largest supported `TileShape::batch`, so every tile shape tiles a full
/// chunk without a remainder split that the unfused path wouldn't have.
pub const PANEL_CHUNK: usize = 8;

/// What the kernel does with the finished accumulator tile.
#[derive(Clone, Copy)]
enum Epilogue {
    /// `Y += acc` (the classic GEMM contract).
    Accumulate,
    /// `Y = acc + bias` (bias indexed in block-row space), optionally clamped
    /// at zero. Writes — does not read — `Y`.
    Fused { relu: bool },
}

/// Shared handle to the output buffer for block tasks. Concurrent tasks must
/// NOT each hold a `&mut` over the whole buffer (aliased `&mut` is undefined
/// behavior even with disjoint writes); instead every write site projects a
/// short-lived `&mut` over exactly its own disjoint row segment.
#[derive(Clone, Copy)]
struct OutPtr {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: tasks write disjoint segments (block row spans partition the
// output columns) and the pool joins all tasks before the caller's `&mut`
// is used again; `row_mut` is the only access path.
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl OutPtr {
    /// Project a mutable view over `n` elements starting at `base`.
    ///
    /// SAFETY (caller): the `[base, base + n)` segment must not overlap any
    /// other live projection — guaranteed here because block row spans are
    /// disjoint and each task projects only rows of its own block.
    #[inline]
    unsafe fn seg_mut(&self, base: usize, n: usize) -> &mut [f32] {
        debug_assert!(base + n <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(base), n)
    }
}

/// A block-diagonal weight matrix in packed storage.
///
/// Semantics: represents `W` of shape `[rows=out × cols=in]` where block `b`
/// occupies `layout.row_spans[b] × layout.col_spans[b]`; everything else is
/// structurally zero (not stored).
#[derive(Clone, Debug)]
pub struct BlockDiagMatrix {
    pub layout: BlockDiagLayout,
    /// Concatenated row-major blocks; block `b` starts at `block_off[b]` and
    /// has `row_spans[b].len * col_spans[b].len` elements.
    pub packed: Vec<f32>,
    pub block_off: Vec<usize>,
}

impl BlockDiagMatrix {
    /// Pack a dense block-diagonal matrix (e.g. the output of
    /// [`MpdMask::unpermute`]). Off-block entries must be zero — checked in
    /// debug builds.
    pub fn from_dense(data: &[f32], layout: &BlockDiagLayout) -> Self {
        debug_assert_eq!(
            crate::mask::blockdiag::off_block_mass(data, layout),
            0.0,
            "matrix is not block-diagonal under this layout"
        );
        let packed = crate::mask::blockdiag::pack_blocks(data, layout);
        Self::from_packed(packed, layout.clone())
    }

    /// Build directly from packed block storage.
    pub fn from_packed(packed: Vec<f32>, layout: BlockDiagLayout) -> Self {
        assert_eq!(packed.len(), layout.nnz());
        let mut block_off = Vec::with_capacity(layout.nblocks() + 1);
        let mut off = 0;
        for b in 0..layout.nblocks() {
            block_off.push(off);
            off += layout.row_spans[b].len * layout.col_spans[b].len;
        }
        block_off.push(off);
        Self { layout, packed, block_off }
    }

    /// One-step pack from a trained masked weight matrix: applies eq. 2
    /// (`W* = P_rowᵀ W̄ P_colᵀ`) then extracts blocks.
    pub fn from_masked_weights(mask: &MpdMask, w_masked: &[f32]) -> Self {
        Self::from_packed(mask.pack(w_masked), mask.layout.clone())
    }

    pub fn nblocks(&self) -> usize {
        self.layout.nblocks()
    }

    /// Stored parameter count (the compressed size).
    pub fn nnz(&self) -> usize {
        self.packed.len()
    }

    /// Bytes of the packed representation: values only, plus one span pair
    /// per block (the entire "index" cost of the format — contrast CSR).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() * 4 + self.layout.nblocks() * 4 * std::mem::size_of::<u32>()
    }

    /// Block `b` as a row-major `(out_b × in_b)` slice.
    #[inline]
    pub fn block(&self, b: usize) -> &[f32] {
        &self.packed[self.block_off[b]..self.block_off[b + 1]]
    }

    /// Expand back to the dense `[rows × cols]` matrix (test/debug helper).
    pub fn to_dense(&self) -> Vec<f32> {
        crate::mask::blockdiag::unpack_blocks(&self.packed, &self.layout)
    }

    /// `Y += X · Wᵀ` with `X: [batch × cols]`, `Y: [batch × rows]`,
    /// both row-major. Sequential over blocks, tiled within each block.
    pub fn matmul_xt(&self, x: &[f32], y: &mut [f32], batch: usize) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols, "X shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        self.run_blocks(x, y, batch, &[], Epilogue::Accumulate, TileShape::DEFAULT, None);
    }

    /// The seed's scalar dot-product kernel, kept as the oracle the tiled and
    /// pooled paths are property-tested (and benchmarked) against.
    pub fn matmul_xt_reference(&self, x: &[f32], y: &mut [f32], batch: usize) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols, "X shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        for b in 0..self.nblocks() {
            let rs = self.layout.row_spans[b];
            let cs = self.layout.col_spans[b];
            let wb = self.block(b);
            for bi in 0..batch {
                let xrow = &x[bi * cols + cs.start..bi * cols + cs.end()];
                let yrow = &mut y[bi * rows + rs.start..bi * rows + rs.end()];
                for (r, yv) in yrow.iter_mut().enumerate() {
                    *yv += crate::linalg::gemm::dot(&wb[r * cs.len..(r + 1) * cs.len], xrow);
                }
            }
        }
    }

    /// Parallel-over-blocks variant on the process-global persistent pool,
    /// capped at `nthreads` lanes. Bit-identical to [`Self::matmul_xt`]:
    /// blocks write disjoint column spans of `Y` and every element keeps its
    /// canonical accumulation order.
    pub fn matmul_xt_parallel(&self, x: &[f32], y: &mut [f32], batch: usize, nthreads: usize) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols);
        assert_eq!(y.len(), batch * rows);
        if nthreads <= 1 {
            return self.matmul_xt(x, y, batch);
        }
        self.run_blocks(x, y, batch, &[], Epilogue::Accumulate, TileShape::DEFAULT, Some((crate::linalg::pool::global(), nthreads)));
    }

    /// [`Self::matmul_xt`] on a caller-owned pool (all lanes).
    pub fn matmul_xt_pooled(&self, x: &[f32], y: &mut [f32], batch: usize, pool: &ThreadPool) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols);
        assert_eq!(y.len(), batch * rows);
        self.run_blocks(x, y, batch, &[], Epilogue::Accumulate, TileShape::DEFAULT, Some((pool, usize::MAX)));
    }

    /// Fused layer forward: `Y[:, rs_b] = X[:, cs_b] · W_bᵀ + bias[rs_b]`,
    /// optionally through ReLU — the packed model's per-layer operation with
    /// the bias copy and activation sweep folded into the block loop. `Y` is
    /// written (not accumulated); `bias` is indexed in block-row space and
    /// must have `rows` entries. Runs on `pool` when given.
    pub fn forward_fused(
        &self,
        x: &[f32],
        y: &mut [f32],
        batch: usize,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
    ) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols, "X shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        self.run_blocks(x, y, batch, bias, Epilogue::Fused { relu }, tile, pool.map(|p| (p, usize::MAX)));
    }

    /// [`Self::forward_fused`] with an explicit kernel ISA — the entry the
    /// executor dispatches through. `Isa::Scalar` is exactly the tiled
    /// scalar oracle above. SIMD ISAs switch to one vectorized dot product
    /// per output element (the vector register *is* the tile, so `tile` is
    /// ignored): the accumulation order then depends only on the ISA and the
    /// block inner dimension — never on tile shape, thread count, or batch —
    /// and differs from the oracle by at most the reassociation bound
    /// `kernel::f32_reorder_bound`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_fused_isa(
        &self,
        x: &[f32],
        y: &mut [f32],
        batch: usize,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
    ) {
        let _span = crate::obs::span("blockdiag_mm_f32");
        if !isa.is_simd() {
            return self.forward_fused(x, y, batch, bias, relu, pool, tile);
        }
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(x.len(), batch * cols, "X shape mismatch");
        assert_eq!(y.len(), batch * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        let nblocks = self.nblocks();
        let yp = OutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let parallel = pool.map(|p| p.lanes() > 1 && nblocks > 1).unwrap_or(false);
        if !parallel {
            for b in 0..nblocks {
                self.block_forward_simd(b, x, yp, batch, bias, relu, isa);
            }
            return;
        }
        // SAFETY of sharing yp: identical to run_blocks — blocks write
        // disjoint row spans and the pool joins before `y`'s borrow returns.
        pool.unwrap().run(nblocks, |b| {
            self.block_forward_simd(b, x, yp, batch, bias, relu, isa);
        });
    }

    /// SIMD per-block kernel: one vectorized dot per output element with the
    /// fused bias + ReLU epilogue (same scalar epilogue as the tiled path).
    fn block_forward_simd(
        &self,
        b: usize,
        x: &[f32],
        yp: OutPtr,
        batch: usize,
        bias: &[f32],
        relu: bool,
        isa: crate::linalg::kernel::Isa,
    ) {
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let (out_b, in_b) = (rs.len, cs.len);
        let wb = self.block(b);
        for bi in 0..batch {
            let xrow = &x[bi * cols + cs.start..bi * cols + cs.end()];
            // SAFETY: rows of block b only — disjoint from all other tasks.
            let yrow = unsafe { yp.seg_mut(bi * rows + rs.start, out_b) };
            for (r, yv) in yrow.iter_mut().enumerate() {
                let wrow = &wb[r * in_b..(r + 1) * in_b];
                let mut v = crate::linalg::kernel::dot_f32(isa, xrow, wrow) + bias[rs.start + r];
                if relu && v < 0.0 {
                    v = 0.0;
                }
                *yv = v;
            }
        }
    }

    /// Widest block reduction dimension — the panel column stride of the
    /// fused pack-gather path.
    pub fn max_block_cols(&self) -> usize {
        self.layout.col_spans.iter().map(|c| c.len).max().unwrap_or(0)
    }

    /// Scratch floats [`Self::forward_panel_isa`] needs: one `PANEL_CHUNK`-row
    /// slab per block. Batch-independent — the fused path never materializes
    /// the full patch/permuted matrix.
    pub fn panel_elems(&self) -> usize {
        self.nblocks() * PANEL_CHUNK * self.max_block_cols()
    }

    /// Implicit-GEMM fused forward: the A-matrix is never materialized.
    /// `src` describes how to gather each block's A-rows straight out of the
    /// upstream activation `x` (im2col patch taps for conv, a permutation for
    /// inter-layer gathers); rows are packed `PANEL_CHUNK` at a time into a
    /// per-block panel slab and multiplied in place.
    ///
    /// `nrows` is the logical A-row count (`batch · patches_per_sample` for
    /// conv, `batch` for FC). Packed values are byte-identical to the
    /// materialized `im2col → gather` pipeline, and both compute paths reuse
    /// the unfused kernels' accumulation order ([`Self::block_forward_at`]
    /// for scalar, one `dot_f32` per output element for SIMD), so fused
    /// output is bit-exact with `forward_fused_isa` over the materialized
    /// matrix under the same ISA.
    ///
    /// `panel` is caller-owned scratch (grown to [`Self::panel_elems`] on
    /// first use, no-op when pre-warmed).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_panel_isa(
        &self,
        x: &[f32],
        y: &mut [f32],
        nrows: usize,
        src: &crate::linalg::im2col::PanelSource<'_>,
        bias: &[f32],
        relu: bool,
        pool: Option<&ThreadPool>,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
        panel: &mut Vec<f32>,
    ) {
        let _span = crate::obs::span("blockdiag_mm_f32_panel");
        let (rows, cols) = (self.layout.rows, self.layout.cols);
        assert_eq!(src.ncols(), cols, "panel source width mismatch");
        assert_eq!(x.len(), src.src_elems_for(nrows), "source shape mismatch");
        assert_eq!(y.len(), nrows * rows, "Y shape mismatch");
        assert_eq!(bias.len(), rows, "bias must be in block-row space");
        let nblocks = self.nblocks();
        let stride = PANEL_CHUNK * self.max_block_cols();
        if panel.len() < nblocks * stride {
            panel.resize(nblocks * stride, 0.0);
        }
        let yp = OutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let pp = OutPtr { ptr: panel.as_mut_ptr(), len: panel.len() };
        let parallel = pool.map(|p| p.lanes() > 1 && nblocks > 1).unwrap_or(false);
        if !parallel {
            for b in 0..nblocks {
                // SAFETY: sequential — one panel projection live at a time.
                let pslice = unsafe { pp.seg_mut(b * stride, stride) };
                self.block_forward_panel(b, x, yp, nrows, src, bias, relu, tile, isa, pslice);
            }
            return;
        }
        pool.unwrap().run(nblocks, |b| {
            // SAFETY of sharing yp/pp: block b writes only its own output
            // row span and its own `[b·stride, (b+1)·stride)` panel slab —
            // both disjoint across blocks — and the pool joins all tasks
            // before the borrows of `y`/`panel` are used again.
            let pslice = unsafe { pp.seg_mut(b * stride, stride) };
            self.block_forward_panel(b, x, yp, nrows, src, bias, relu, tile, isa, pslice);
        });
    }

    /// One block of the fused path: pack `PANEL_CHUNK` A-rows of this
    /// block's column span into the panel slab, multiply, repeat. Scalar ISA
    /// goes through the shared tiled micro-kernel; SIMD does one `dot_f32`
    /// per output element, exactly like [`Self::block_forward_simd`].
    #[allow(clippy::too_many_arguments)]
    fn block_forward_panel(
        &self,
        b: usize,
        x: &[f32],
        yp: OutPtr,
        nrows: usize,
        src: &crate::linalg::im2col::PanelSource<'_>,
        bias: &[f32],
        relu: bool,
        tile: TileShape,
        isa: crate::linalg::kernel::Isa,
        pslice: &mut [f32],
    ) {
        let rows = self.layout.rows;
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let (out_b, in_b) = (rs.len, cs.len);
        let wb = self.block(b);
        for row0 in (0..nrows).step_by(PANEL_CHUNK) {
            let nr = PANEL_CHUNK.min(nrows - row0);
            for i in 0..nr {
                src.pack_row(x, row0 + i, cs.start, &mut pslice[i * in_b..(i + 1) * in_b]);
            }
            if !isa.is_simd() {
                self.block_forward_at(
                    b,
                    pslice,
                    in_b,
                    0,
                    yp,
                    row0,
                    nr,
                    bias,
                    Epilogue::Fused { relu },
                    tile,
                );
                continue;
            }
            for i in 0..nr {
                let prow = &pslice[i * in_b..(i + 1) * in_b];
                // SAFETY: rows of block b only — disjoint from all other tasks.
                let yrow = unsafe { yp.seg_mut((row0 + i) * rows + rs.start, out_b) };
                for (r, yv) in yrow.iter_mut().enumerate() {
                    let wrow = &wb[r * in_b..(r + 1) * in_b];
                    let mut v =
                        crate::linalg::kernel::dot_f32(isa, prow, wrow) + bias[rs.start + r];
                    if relu && v < 0.0 {
                        v = 0.0;
                    }
                    *yv = v;
                }
            }
        }
    }

    /// Shared driver: run every block through the kernel, sequentially or on
    /// a pool.
    fn run_blocks(
        &self,
        x: &[f32],
        y: &mut [f32],
        batch: usize,
        bias: &[f32],
        ep: Epilogue,
        tile: TileShape,
        pool: Option<(&ThreadPool, usize)>,
    ) {
        let nblocks = self.nblocks();
        // One raw handle for all block tasks; every write projects a
        // short-lived &mut over its own disjoint rows only (see OutPtr).
        let yp = OutPtr { ptr: y.as_mut_ptr(), len: y.len() };
        let parallel = match pool {
            Some((p, cap)) => p.lanes().min(cap) > 1 && nblocks > 1,
            None => false,
        };
        if !parallel {
            for b in 0..nblocks {
                self.block_forward(b, x, yp, batch, bias, ep, tile);
            }
            return;
        }
        let (p, cap) = pool.unwrap();
        p.run_capped(nblocks, cap, |b| {
            // SAFETY of sharing yp: block b writes only Y[:, row_spans[b]] —
            // row spans are disjoint across blocks, so no two tasks ever
            // project overlapping segments, and the pool guarantees all
            // tasks finish before `run_capped` (and thus the borrow of `y`)
            // returns.
            self.block_forward(b, x, yp, batch, bias, ep, tile);
        });
    }

    /// Per-block kernel entry for the materialized-A path: the block reads
    /// its A-rows straight out of the full activation matrix (`ldx = cols`,
    /// row offset `cs.start`).
    fn block_forward(
        &self,
        b: usize,
        x: &[f32],
        yp: OutPtr,
        batch: usize,
        bias: &[f32],
        ep: Epilogue,
        tile: TileShape,
    ) {
        let cs = self.layout.col_spans[b];
        self.block_forward_at(b, x, self.layout.cols, cs.start, yp, 0, batch, bias, ep, tile);
    }

    /// Tile-shape dispatch onto a monomorphized micro-kernel, generalized
    /// over where the block's A-rows live: local row `i` is
    /// `x[xoff + i·ldx ..][..in_b]` and writes output row `y_row0 + i`.
    /// The unfused path passes the whole activation (`ldx = cols`,
    /// `xoff = cs.start`, `y_row0 = 0`); the fused panel path passes a packed
    /// `[nloc × in_b]` chunk (`ldx = in_b`, `xoff = 0`) at its global row
    /// offset — one 16-arm dispatch serves both, so the fused kernels can
    /// never drift from the canonical accumulation order.
    #[allow(clippy::too_many_arguments)]
    fn block_forward_at(
        &self,
        b: usize,
        x: &[f32],
        ldx: usize,
        xoff: usize,
        yp: OutPtr,
        y_row0: usize,
        nloc: usize,
        bias: &[f32],
        ep: Epilogue,
        tile: TileShape,
    ) {
        // Every shape TileShape::validate accepts has its own monomorphized
        // kernel — a configured shape is never silently substituted. Shapes
        // that would fail validation (only reachable by constructing a
        // TileShape by hand) fall back to the default kernel.
        match (tile.batch, tile.rows) {
            (1, 1) => self.block_forward_t::<1, 1>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 2) => self.block_forward_t::<1, 2>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 4) => self.block_forward_t::<1, 4>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (1, 8) => self.block_forward_t::<1, 8>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 1) => self.block_forward_t::<2, 1>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 2) => self.block_forward_t::<2, 2>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 4) => self.block_forward_t::<2, 4>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (2, 8) => self.block_forward_t::<2, 8>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 1) => self.block_forward_t::<4, 1>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 2) => self.block_forward_t::<4, 2>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 4) => self.block_forward_t::<4, 4>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (4, 8) => self.block_forward_t::<4, 8>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 1) => self.block_forward_t::<8, 1>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 2) => self.block_forward_t::<8, 2>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 4) => self.block_forward_t::<8, 4>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            (8, 8) => self.block_forward_t::<8, 8>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep),
            _ => {
                debug_assert!(false, "unvalidated tile shape {tile:?}");
                self.block_forward_t::<4, 8>(b, x, ldx, xoff, yp, y_row0, nloc, bias, ep)
            }
        }
    }

    /// The tiled micro-GEMM over one block, `TM × TN` register tiles.
    #[allow(clippy::too_many_arguments)]
    fn block_forward_t<const TM: usize, const TN: usize>(
        &self,
        b: usize,
        x: &[f32],
        ldx: usize,
        xoff: usize,
        yp: OutPtr,
        y_row0: usize,
        nloc: usize,
        bias: &[f32],
        ep: Epilogue,
    ) {
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let rows = self.layout.rows;
        let wb = self.block(b); // (rs.len × cs.len), row-major
        let (out_b, in_b) = (rs.len, cs.len);
        let mb = nloc - nloc % TM;
        let nb = out_b - out_b % TN;

        for bi0 in (0..mb).step_by(TM) {
            for r0 in (0..nb).step_by(TN) {
                // Full TM×TN tile. Slices pinned up front so the inner loop
                // indexes with in-bounds-provable offsets.
                let mut xrows = [&x[..0]; TM];
                for (i, xr) in xrows.iter_mut().enumerate() {
                    let base = xoff + (bi0 + i) * ldx;
                    *xr = &x[base..base + in_b];
                }
                let mut wrows = [&wb[..0]; TN];
                for (j, wr) in wrows.iter_mut().enumerate() {
                    *wr = &wb[(r0 + j) * in_b..(r0 + j + 1) * in_b];
                }
                let mut acc = [[0.0f32; TN]; TM];
                for p in 0..in_b {
                    for i in 0..TM {
                        let xv = xrows[i][p];
                        for j in 0..TN {
                            acc[i][j] += xv * wrows[j][p];
                        }
                    }
                }
                for i in 0..TM {
                    let base = (y_row0 + bi0 + i) * rows + rs.start + r0;
                    // SAFETY: rows of this block only — disjoint across tasks.
                    let yrow = unsafe { yp.seg_mut(base, TN) };
                    match ep {
                        Epilogue::Accumulate => {
                            for j in 0..TN {
                                yrow[j] += acc[i][j];
                            }
                        }
                        Epilogue::Fused { relu } => {
                            for j in 0..TN {
                                let mut v = acc[i][j] + bias[rs.start + r0 + j];
                                if relu && v < 0.0 {
                                    v = 0.0;
                                }
                                yrow[j] = v;
                            }
                        }
                    }
                }
            }
        }
        // Remainder regions, same p-ascending accumulation order as the
        // tiles so element values are path-independent:
        //   A: full-tile batch rows × leftover output rows
        //   B: leftover batch rows × all output rows
        if nb < out_b {
            self.block_scalar(b, x, ldx, xoff, yp, y_row0, bias, ep, 0..mb, nb..out_b);
        }
        if mb < nloc {
            self.block_scalar(b, x, ldx, xoff, yp, y_row0, bias, ep, mb..nloc, 0..out_b);
        }
    }

    /// Scalar cell path for tile remainders (and the 1×1 "tile"), with the
    /// same `(ldx, xoff, y_row0)` A-row addressing as [`Self::block_forward_at`].
    #[allow(clippy::too_many_arguments)]
    fn block_scalar(
        &self,
        b: usize,
        x: &[f32],
        ldx: usize,
        xoff: usize,
        yp: OutPtr,
        y_row0: usize,
        bias: &[f32],
        ep: Epilogue,
        bi_range: std::ops::Range<usize>,
        r_range: std::ops::Range<usize>,
    ) {
        let rs = self.layout.row_spans[b];
        let cs = self.layout.col_spans[b];
        let rows = self.layout.rows;
        let wb = self.block(b);
        let in_b = cs.len;
        for bi in bi_range {
            let xrow = &x[xoff + bi * ldx..xoff + bi * ldx + in_b];
            for r in r_range.clone() {
                let wrow = &wb[r * in_b..(r + 1) * in_b];
                let mut acc = 0.0f32;
                for p in 0..in_b {
                    acc += xrow[p] * wrow[p];
                }
                let idx = (y_row0 + bi) * rows + rs.start + r;
                // SAFETY: a cell of this block's own rows — disjoint across tasks.
                let cell = unsafe { yp.seg_mut(idx, 1) };
                match ep {
                    Epilogue::Accumulate => cell[0] += acc,
                    Epilogue::Fused { relu } => {
                        let mut v = acc + bias[rs.start + r];
                        if relu && v < 0.0 {
                            v = 0.0;
                        }
                        cell[0] = v;
                    }
                }
            }
        }
    }

    /// Single-sample `y += W·x` (serving fast path, batch=1 without the
    /// batch-loop overhead).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        self.matmul_xt(x, y, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_a_bt;
    use crate::mask::prng::Xoshiro256pp;

    fn mk(rows: usize, cols: usize, k: usize, rng: &mut Xoshiro256pp) -> (BlockDiagMatrix, Vec<f32>) {
        // random dense block-diagonal matrix + its dense form
        let layout = BlockDiagLayout::new(rows, cols, k);
        let mut dense = vec![0.0f32; rows * cols];
        for (b, rs) in layout.row_spans.iter().enumerate() {
            let cs = layout.col_spans[b];
            for r in rs.start..rs.end() {
                for c in cs.start..cs.end() {
                    dense[r * cols + c] = rng.next_f32() * 2.0 - 1.0;
                }
            }
        }
        (BlockDiagMatrix::from_dense(&dense, &layout), dense)
    }

    #[test]
    fn matmul_matches_dense_gemm() {
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        for (rows, cols, k, batch) in [(10, 8, 2, 1), (300, 100, 10, 4), (33, 44, 11, 7), (16, 16, 16, 3)] {
            let (bd, dense) = mk(rows, cols, k, &mut rng);
            let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32()).collect();
            let mut y1 = vec![0.0f32; batch * rows];
            bd.matmul_xt(&x, &mut y1, batch);
            let mut y2 = vec![0.0f32; batch * rows];
            gemm_a_bt(&x, &dense, &mut y2, batch, cols, rows);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4, "{rows}x{cols} k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tiled_matches_scalar_reference() {
        let mut rng = Xoshiro256pp::seed_from_u64(46);
        for (rows, cols, k, batch) in [(13, 9, 3, 1), (300, 784, 10, 32), (40, 40, 5, 6), (7, 7, 7, 9)] {
            let (bd, _) = mk(rows, cols, k, &mut rng);
            let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32() - 0.5).collect();
            let init: Vec<f32> = (0..batch * rows).map(|_| rng.next_f32()).collect();
            let mut y_ref = init.clone();
            bd.matmul_xt_reference(&x, &mut y_ref, batch);
            let mut y_tiled = init.clone();
            bd.matmul_xt(&x, &mut y_tiled, batch);
            for (a, b) in y_tiled.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-4, "{rows}x{cols} k={k} b={batch}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn all_tile_shapes_agree_exactly() {
        // Element values must be identical across tile shapes (canonical
        // p-ascending accumulation), so config changes can't shift numerics.
        let mut rng = Xoshiro256pp::seed_from_u64(47);
        let (bd, _) = mk(45, 31, 4, &mut rng);
        let batch = 11;
        let x: Vec<f32> = (0..batch * 31).map(|_| rng.next_f32() - 0.5).collect();
        let bias: Vec<f32> = (0..45).map(|_| rng.next_f32() - 0.5).collect();
        let mut y_default = vec![0.0f32; batch * 45];
        bd.forward_fused(&x, &mut y_default, batch, &bias, true, None, TileShape::DEFAULT);
        for (tm, tn) in [(1, 1), (1, 4), (1, 8), (2, 2), (2, 4), (2, 8), (4, 4), (8, 8)] {
            let tile = TileShape { batch: tm, rows: tn };
            tile.validate().unwrap();
            let mut y = vec![0.0f32; batch * 45];
            bd.forward_fused(&x, &mut y, batch, &bias, true, None, tile);
            assert_eq!(y, y_default, "tile {tm}x{tn}");
        }
        assert!(TileShape { batch: 3, rows: 8 }.validate().is_err());
    }

    #[test]
    fn fused_equals_unfused_composition() {
        let mut rng = Xoshiro256pp::seed_from_u64(48);
        for relu in [false, true] {
            let (bd, _) = mk(30, 24, 3, &mut rng);
            let batch = 5;
            let x: Vec<f32> = (0..batch * 24).map(|_| rng.next_f32() - 0.5).collect();
            let bias: Vec<f32> = (0..30).map(|_| rng.next_f32() - 0.5).collect();
            // unfused: bias-init, accumulate, then activation sweep
            let mut y_ref = vec![0.0f32; batch * 30];
            for bi in 0..batch {
                y_ref[bi * 30..(bi + 1) * 30].copy_from_slice(&bias);
            }
            bd.matmul_xt(&x, &mut y_ref, batch);
            if relu {
                y_ref.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            let mut y_fused = vec![0.0f32; batch * 30];
            bd.forward_fused(&x, &mut y_fused, batch, &bias, relu, None, TileShape::DEFAULT);
            assert_eq!(y_fused, y_ref, "relu={relu}");
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let (bd, _) = mk(120, 90, 6, &mut rng);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 90).map(|_| rng.next_f32()).collect();
        let mut y_seq = vec![0.0f32; batch * 120];
        bd.matmul_xt(&x, &mut y_seq, batch);
        for nthreads in [2, 3, 8] {
            let mut y_par = vec![0.0f32; batch * 120];
            bd.matmul_xt_parallel(&x, &mut y_par, batch, nthreads);
            assert_eq!(y_seq, y_par, "nthreads={nthreads}");
        }
        // caller-owned pool path
        let pool = ThreadPool::new(4);
        let mut y_pool = vec![0.0f32; batch * 120];
        bd.matmul_xt_pooled(&x, &mut y_pool, batch, &pool);
        assert_eq!(y_seq, y_pool);
    }

    #[test]
    fn from_masked_weights_equals_masked_dense_product() {
        // end-to-end eq.-2 path: y from packed blocks on permuted input ==
        // y from the masked dense matrix on raw input, modulo permutations.
        let mut rng = Xoshiro256pp::seed_from_u64(43);
        let (rows, cols, k, batch) = (30, 20, 5, 3);
        let mask = MpdMask::generate(rows, cols, k, &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() - 0.5).collect();
        let w_masked = mask.apply(&w);
        let bd = BlockDiagMatrix::from_masked_weights(&mask, &w_masked);

        let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32()).collect();
        // reference: y = x · W̄ᵀ
        let mut y_ref = vec![0.0f32; batch * rows];
        gemm_a_bt(&x, &w_masked, &mut y_ref, batch, cols, rows);

        // packed path: x' = P_col⁻¹ x per sample; y' = blockdiag(x'); y = P_row y'
        // (x_{P_col} in the paper is P_col(d_i)·x — with our forward-map
        // convention W* = unpermute(W̄) has W*[r'][c'] = W̄[p_row(r')][p_col(c')],
        // so x'[c'] must equal x[p_col(c')], i.e. x' = p_col⁻¹ applied... use
        // apply_vec of inverse: x'[inv.dest(c)] = x[c] with inv = p_col⁻¹ means
        // x'[c'] = x[p_col(c')]. Check: inv.dest(c) = c' where p_col.dest(c') = c.
        let p_col_inv = mask.p_col.inverse();
        let p_row_inv = mask.p_row.inverse();
        let mut y_packed = vec![0.0f32; batch * rows];
        for bi in 0..batch {
            let xs = &x[bi * cols..(bi + 1) * cols];
            let xp = p_col_inv.apply_vec(xs);
            let mut yp = vec![0.0f32; rows];
            bd.matvec(&xp, &mut yp);
            // yp is in permuted (block) space: yp[r'] = y[p_row(r')] ⇒ y = apply p_row…
            let yo = p_row_inv.inverse().apply_vec(&yp); // p_row applied: y[p_row.dest? ]
            // p_row_inv.inverse() == p_row; apply_vec: y[p_row.dest(r')] = yp[r']  ✓
            y_packed[bi * rows..(bi + 1) * rows].copy_from_slice(&yo);
        }
        for (a, b) in y_packed.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_compressed() {
        let mut rng = Xoshiro256pp::seed_from_u64(44);
        let (bd, _) = mk(300, 100, 10, &mut rng);
        assert_eq!(bd.nnz(), 3000);
        assert!(bd.storage_bytes() < 300 * 100 * 4 / 9, "≥9× byte compression expected");
    }

    #[test]
    fn to_dense_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(45);
        let (bd, dense) = mk(24, 36, 4, &mut rng);
        assert_eq!(bd.to_dense(), dense);
    }

    #[test]
    fn panel_gather_fused_is_bit_exact_with_materialized() {
        // forward_panel_isa over a permutation source must equal
        // gather → forward_fused_isa over the materialized matrix exactly,
        // for every tile shape, pool width, and dispatch ISA.
        use crate::linalg::im2col::PanelSource;
        let mut rng = Xoshiro256pp::seed_from_u64(51);
        let (rows, cols, k, batch) = (45, 31, 4, 11);
        let (bd, _) = mk(rows, cols, k, &mut rng);
        let bias: Vec<f32> = (0..rows).map(|_| rng.next_f32() - 0.5).collect();
        // random permutation of the source columns (an inter-layer gather)
        let src_dim = cols + 3;
        let mut idx: Vec<u32> = (0..cols as u32).collect();
        for i in (1..idx.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            idx.swap(i, j);
        }
        let x: Vec<f32> = (0..batch * src_dim).map(|_| rng.next_f32() - 0.5).collect();
        // materialized reference: gather then forward
        let mut xg = vec![0.0f32; batch * cols];
        for bi in 0..batch {
            for (c, &s) in idx.iter().enumerate() {
                xg[bi * cols + c] = x[bi * src_dim + s as usize];
            }
        }
        let src = PanelSource::Gather { idx: &idx, src_dim };
        let isas = [crate::linalg::kernel::Isa::Scalar, crate::linalg::kernel::KernelChoice::auto().f32_isa()];
        for isa in isas {
            let mut y_ref = vec![0.0f32; batch * rows];
            bd.forward_fused_isa(&xg, &mut y_ref, batch, &bias, true, None, TileShape::DEFAULT, isa);
            for (tm, tn) in [(1, 1), (2, 8), (4, 8), (8, 2)] {
                let tile = TileShape { batch: tm, rows: tn };
                for lanes in [0usize, 2, 8] {
                    let pool = if lanes == 0 { None } else { Some(ThreadPool::new(lanes)) };
                    let mut y = vec![0.0f32; batch * rows];
                    let mut panel = Vec::new();
                    bd.forward_panel_isa(
                        &x, &mut y, batch, &src, &bias, true, pool.as_ref(), tile, isa, &mut panel,
                    );
                    // SIMD ignores tile; the scalar path's canonical
                    // p-ascending accumulation makes values tile-independent.
                    assert_eq!(y, y_ref, "isa={isa:?} tile={tm}x{tn} lanes={lanes}");
                    assert_eq!(panel.len(), bd.panel_elems());
                }
            }
        }
    }

    #[test]
    fn panel_im2col_fused_is_bit_exact_with_materialized() {
        // implicit-GEMM conv: pack-gather straight from NCHW == im2col →
        // P_col gather → forward_fused_isa, bit for bit.
        use crate::linalg::im2col::{gather_cols, im2col, patch_taps, ConvShape, PanelSource};
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let s = ConvShape { in_c: 3, h: 7, w: 6, kh: 3, kw: 3, stride: 2, pad: 1 };
        let pdim = s.patch_dim();
        let (oh, ow) = s.out_hw();
        let batch = 2;
        let nrows = batch * oh * ow;
        let (bd, _) = mk(10, pdim, 2, &mut rng);
        let bias: Vec<f32> = (0..10).map(|_| rng.next_f32() - 0.5).collect();
        let mut perm: Vec<u32> = (0..pdim as u32).collect();
        for i in (1..perm.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            perm.swap(i, j);
        }
        let x: Vec<f32> = (0..batch * s.in_dim()).map(|_| rng.next_f32() - 0.5).collect();
        // materialized pipeline
        let mut patches = vec![0.0f32; nrows * pdim];
        im2col(&x, batch, &s, &mut patches);
        let mut gathered = vec![0.0f32; nrows * pdim];
        gather_cols(&patches, nrows, pdim, &perm, &mut gathered);
        let mut y_ref = vec![0.0f32; nrows * 10];
        bd.forward_fused_isa(&gathered, &mut y_ref, nrows, &bias, false, None, TileShape::DEFAULT, crate::linalg::kernel::Isa::Scalar);
        // fused path
        let taps = patch_taps(&s, Some(&perm));
        let src = PanelSource::Im2col { shape: &s, taps: &taps };
        let pool = ThreadPool::new(2);
        for pool_opt in [None, Some(&pool)] {
            let mut y = vec![0.0f32; nrows * 10];
            let mut panel = Vec::new();
            bd.forward_panel_isa(
                &x, &mut y, nrows, &src, &bias, false, pool_opt, TileShape::DEFAULT,
                crate::linalg::kernel::Isa::Scalar, &mut panel,
            );
            assert_eq!(y, y_ref);
        }
    }
}
