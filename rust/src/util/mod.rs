//! Shared utilities: bench harness, mini property testing, JSON-lite, PGM
//! figures, CRC32.
pub mod benchkit;
pub mod crc32;
pub mod json;
pub mod pgm;
pub mod prop;

pub use json::Json;
