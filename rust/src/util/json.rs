//! Minimal JSON writer + parser ("json-lite").
//!
//! Used for: result rows (`results/*.jsonl`), artifact metadata sidecars
//! written by `python/compile/aot.py`, and loss-curve logging. We support the
//! JSON subset those producers emit: objects, arrays, strings (with \" \\ \n
//! \t \u escapes), numbers, booleans, null. No trailing commas, no comments.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly (single line — suitable for JSONL).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{}", v);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: collect continuation bytes
                    let extra = if c >= 0xF0 {
                        3
                    } else if c >= 0xE0 {
                        2
                    } else {
                        1
                    };
                    let start = self.pos - 1;
                    for _ in 0..extra {
                        self.bump().ok_or("truncated utf8")?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|e| format!("bad utf8: {e}"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }
}

/// Append one JSON object as a line to a JSONL file, creating parents.
pub fn append_jsonl(path: &std::path::Path, row: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", row.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("lenet")),
            ("acc", Json::num(0.973)),
            ("steps", Json::num(500)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::str("a"), Json::str("b")])),
            ("none", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested_and_ws() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , { \"b\" : null } ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""line\nquote\" tab\t uA""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nquote\" tab\t uA"));
        let round = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&round).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo — ümlaut\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ümlaut"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
