//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — integrity check
//! for the checkpoint format in `nn::checkpoint`. Table-driven, byte at a
//! time; matches zlib's `crc32()`.

// Built at compile time — no lazy-init dependency needed offline.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Streaming CRC-32.
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.state = TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"a"), 0xE8B7BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut s = Crc32::new();
        s.update(&data[..10]);
        s.update(&data[10..]);
        assert_eq!(s.finish(), crc32(data));
    }
}
