//! PGM (portable graymap) writer — used to regenerate the paper's mask
//! figures (Fig. 1(e,f): block-diagonal matrix B₁ and permuted mask M₁;
//! Fig. 4(b): sum of 100 masks). PGM is chosen because it needs no codec:
//! any image viewer opens it and the bytes are trivially testable.

use std::io::Write;
use std::path::Path;

/// Write a `rows × cols` f32 matrix as an 8-bit PGM, linearly mapping
/// `[min, max]` of the data to `[0, 255]` (constant matrices map to 0).
pub fn write_pgm(path: &Path, data: &[f32], rows: usize, cols: usize) -> std::io::Result<()> {
    assert_eq!(data.len(), rows * cols);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let lo = data.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut buf = Vec::with_capacity(rows * cols + 32);
    write!(buf, "P5\n{cols} {rows}\n255\n")?;
    for &v in data {
        buf.push(((v - lo) * scale).round().clamp(0.0, 255.0) as u8);
    }
    std::fs::write(path, buf)
}

/// Parse the header + pixels of an 8-bit binary PGM (test helper / loader).
pub fn read_pgm(path: &Path) -> std::io::Result<(Vec<u8>, usize, usize)> {
    let bytes = std::fs::read(path)?;
    let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    // header: P5 <ws> cols <ws> rows <ws> maxval <single ws> pixels
    let mut fields = Vec::new();
    let mut pos = 0usize;
    while fields.len() < 4 && pos < bytes.len() {
        while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if pos < bytes.len() && bytes[pos] == b'#' {
            while pos < bytes.len() && bytes[pos] != b'\n' {
                pos += 1;
            }
            continue;
        }
        let start = pos;
        while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        fields.push(std::str::from_utf8(&bytes[start..pos]).map_err(|_| err("bad header"))?.to_string());
    }
    if fields.len() < 4 || fields[0] != "P5" {
        return Err(err("not a binary PGM"));
    }
    let cols: usize = fields[1].parse().map_err(|_| err("bad cols"))?;
    let rows: usize = fields[2].parse().map_err(|_| err("bad rows"))?;
    pos += 1; // the single whitespace after maxval
    let pixels = bytes[pos..].to_vec();
    if pixels.len() != rows * cols {
        return Err(err("pixel count mismatch"));
    }
    Ok((pixels, rows, cols))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("mpdc_pgm_test");
        let path = dir.join("t.pgm");
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        write_pgm(&path, &data, 3, 4).unwrap();
        let (px, rows, cols) = read_pgm(&path).unwrap();
        assert_eq!((rows, cols), (3, 4));
        assert_eq!(px[0], 0);
        assert_eq!(px[11], 255);
        // monotone ramp
        assert!(px.windows(2).all(|w| w[0] <= w[1]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn constant_matrix_is_black() {
        let dir = std::env::temp_dir().join("mpdc_pgm_test2");
        let path = dir.join("c.pgm");
        write_pgm(&path, &[5.0; 6], 2, 3).unwrap();
        let (px, _, _) = read_pgm(&path).unwrap();
        assert!(px.iter().all(|&p| p == 0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
