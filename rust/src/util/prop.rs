//! A miniature property-testing harness (no external crates available in the
//! offline build, so we provide the 10% of proptest we need: seeded random
//! case generation, a fixed case budget, and failure reporting that prints
//! the case seed so a failure is reproducible with `PROP_SEED=<n>`).

use crate::mask::prng::Xoshiro256pp;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Run `prop(case_rng, case_index)` for `default_cases()` seeded cases.
/// Panics (with the failing case seed) if the property panics.
pub fn for_all(name: &str, mut prop: impl FnMut(&mut Xoshiro256pp, usize)) {
    let cases = default_cases();
    let seed = base_seed();
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256pp::seed_from_u64(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!(
                "property `{name}` failed at case {case}/{cases} \
                 (reproduce with PROP_SEED={seed} — case seed {case_seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Uniform usize in `[lo, hi]` inclusive.
pub fn gen_range(rng: &mut Xoshiro256pp, lo: usize, hi: usize) -> usize {
    assert!(hi >= lo);
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

/// Random f32 vector in `[-1, 1)`.
pub fn gen_vec(rng: &mut Xoshiro256pp, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

/// Random sparse f32 vector with the given density.
pub fn gen_sparse_vec(rng: &mut Xoshiro256pp, n: usize, density: f64) -> Vec<f32> {
    (0..n)
        .map(|_| if rng.next_f64() < density { rng.next_f32() * 2.0 - 1.0 } else { 0.0 })
        .collect()
}

/// Assert element-wise closeness with a mixed absolute/relative tolerance.
pub fn assert_allclose(a: &[f32], b: &[f32], tol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!((x - y).abs() <= tol * scale, "{ctx}: idx {i}: {x} vs {y} (tol {tol})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all("counter", |_, _| count += 1);
        assert_eq!(count, default_cases());
    }

    #[test]
    #[should_panic]
    fn for_all_propagates_failure() {
        for_all("fails", |rng, _| {
            assert!(rng.next_f64() < 2.0); // always true
            panic!("boom");
        });
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for _ in 0..1000 {
            let v = gen_range(&mut rng, 3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(gen_range(&mut rng, 5, 5), 5);
    }

    #[test]
    fn allclose_tolerates_scale() {
        assert_allclose(&[1000.0], &[1000.05], 1e-4, "scaled");
    }
}
