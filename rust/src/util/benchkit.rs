//! In-repo micro/macro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, a fixed-time measurement loop, and robust statistics
//! (median + MAD + percentiles over per-iteration timings). All `cargo
//! bench` targets in `rust/benches/` are `harness = false` binaries built on
//! this module; they print both human-readable tables and machine-readable
//! JSONL rows into `results/`.

use std::time::{Duration, Instant};

/// Repo-root `results/` directory, resolved from the crate manifest so bench
/// binaries write the same place regardless of the invocation CWD (cargo
/// runs benches from `rust/`; the committed `results/BENCH_*.json` artifacts
/// live at the repository root). Creates the directory if missing.
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../results");
    std::fs::create_dir_all(&dir).expect("create results dir");
    dir
}

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }

    /// Throughput in ops/s given `work` logical operations per iteration.
    pub fn ops_per_sec(&self, work: f64) -> f64 {
        work / (self.median_ns / 1e9)
    }

    pub fn human(&self) -> String {
        format!(
            "{:<40} {:>10.2} µs median ({:>8.2}..{:>8.2} p10/p90, {} iters)",
            self.name,
            self.median_ns / 1e3,
            self.p10_ns / 1e3,
            self.p90_ns / 1e3,
            self.iters
        )
    }
}

/// Benchmark a closure: warm up for `warmup`, then measure iterations until
/// `measure` wall time has elapsed (at least `min_iters`).
pub fn bench(name: &str, warmup: Duration, measure: Duration, min_iters: usize, mut f: impl FnMut()) -> BenchStats {
    // warmup
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        f();
    }
    // measure
    let mut samples_ns: Vec<f64> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples_ns.len() < min_iters {
        let t = Instant::now();
        f();
        samples_ns.push(t.elapsed().as_nanos() as f64);
        if samples_ns.len() > 5_000_000 {
            break; // safety valve for ~ns-scale bodies
        }
    }
    stats_from(name, samples_ns)
}

/// Quick preset: 0.2 s warmup, 1 s measurement, ≥10 iterations.
pub fn bench_quick(name: &str, f: impl FnMut()) -> BenchStats {
    bench(name, Duration::from_millis(200), Duration::from_secs(1), 10, f)
}

fn stats_from(name: &str, mut samples: Vec<f64>) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    let pct = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
        min_ns: samples[0],
        max_ns: samples[n - 1],
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("spin", Duration::from_millis(5), Duration::from_millis(30), 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.p10_ns <= s.p90_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["xxxxx".into(), "y".into()]);
        let r = t.render();
        assert!(r.contains("| a     | bbbb |"), "{r}");
        assert!(r.lines().count() == 3);
    }

    #[test]
    fn ops_per_sec() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6,
            median_ns: 1e6,
            p10_ns: 1e6,
            p90_ns: 1e6,
            min_ns: 1e6,
            max_ns: 1e6,
        };
        assert!((s.ops_per_sec(1000.0) - 1e6).abs() < 1.0);
    }
}
