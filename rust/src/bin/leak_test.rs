//! Resource-leak regression checks.
//!
//! **Zero-allocation section (always runs, first):** the process installs a
//! counting global allocator; after warming a [`ScratchArena`],
//! `Executor::run_into` must perform **zero** heap allocations per call on
//! the single-threaded path — for the f32-packed, int8, and conv plans.
//! This is the executor's hot-path contract, asserted exactly (an
//! allocation count, not an RSS heuristic).
//!
//! **Pool/batcher section (always runs):** the persistent-pool engine must
//! not leak OS threads or memory across pool lifecycles or across thousands
//! of served batches. We drive many create→run→drop pool cycles and a
//! batcher serving loop over a pooled packed model behind the generic
//! `PlanBackend`, then assert the process thread count returns to baseline
//! and RSS growth stays bounded.
//!
//! **PJRT section (needs artifacts + the `pjrt` feature):** the upstream
//! `xla` crate leaked one device copy of every input argument per `execute`
//! call — ~2.4 MB/step for the LeNet train step, which OOM-killed long
//! sweeps like the Fig. 4(a) 100-mask run. We carry a patched crate; this
//! section runs 200 train steps and fails if RSS grows by more than 64 MB.
//!
//! ```bash
//! cargo run --release --bin leak_test
//! ```

use mpdc::compress::compressor::MpdCompressor;
use mpdc::compress::plan::SparsityPlan;
use mpdc::exec::ScratchArena;
use mpdc::linalg::pool::ThreadPool;
use mpdc::runtime::engine::{Engine, Value};
use mpdc::runtime::manifest::{default_artifact_dir, DType, Manifest};
use mpdc::server::batcher::{spawn, BatcherConfig, PlanBackend};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Global allocator wrapper that counts every allocation (and realloc).
/// Deallocations are free to happen; the zero-alloc assertion is about new
/// heap acquisitions on the hot path.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// `Executor::run_into` must allocate nothing after arena warm-up. Runs
/// before anything spawns threads, so the allocation counter is exact.
fn run_into_zero_alloc_check() -> anyhow::Result<()> {
    use mpdc::compress::conv_model::PackedConvNet;
    use mpdc::compress::{ConvCompressor, ConvModelPlan};
    use mpdc::quant::{Calibration, QuantizedMlp};

    let comp = MpdCompressor::new(SparsityPlan::lenet300(10), 7);
    let (weights, biases) = comp.random_masked_weights(7);
    let conv_comp = ConvCompressor::new(ConvModelPlan::deep_mnist_lite(8), 7);
    let cparams = conv_comp.random_masked_params(7);
    // The residual model exercises the arena's pinned skip slots (SkipSave /
    // ResidualAdd) plus avg- and global-avg-pool; the alexnet-lite model the
    // strided + grouped conv lowering. Both must hold the zero-alloc contract.
    let res_comp = ConvCompressor::new(ConvModelPlan::tinyresnet(8, 16), 7);
    let rparams = res_comp.random_masked_params(7);
    let alex_comp = ConvCompressor::new(ConvModelPlan::alexnet_lite(8, 16), 7);
    let aparams = alex_comp.random_masked_params(7);
    // The kernel choice is resolved once at executor construction (ISSUE 6);
    // both the forced-scalar and the detected-SIMD dispatch must stay
    // zero-alloc on the warmed path — no per-call feature probes or
    // environment reads.
    use mpdc::linalg::KernelChoice;
    let execs = [
        (
            "mpd-f32",
            mpdc::compress::PackedMlp::build(&comp, &weights, &biases).into_executor(),
        ),
        (
            "mpd-f32-scalar",
            mpdc::compress::PackedMlp::build(&comp, &weights, &biases)
                .into_executor()
                .with_kernel(KernelChoice::scalar()),
        ),
        (
            "mpd-f32-simd",
            mpdc::compress::PackedMlp::build(&comp, &weights, &biases)
                .into_executor()
                .with_kernel(KernelChoice::detected()),
        ),
        (
            "mpd-int8",
            QuantizedMlp::quantize(&comp, &weights, &biases, &Calibration::unit_range(3))
                .map_err(anyhow::Error::msg)?
                .into_executor(),
        ),
        (
            "mpd-int8-simd",
            QuantizedMlp::quantize(&comp, &weights, &biases, &Calibration::unit_range(3))
                .map_err(anyhow::Error::msg)?
                .into_executor()
                .with_kernel(KernelChoice::detected()),
        ),
        // Profiling-enabled executors share the contract: the per-op clamp
        // writes pre-sized atomics only (ISSUE 8).
        (
            "mpd-f32-prof",
            mpdc::compress::PackedMlp::build(&comp, &weights, &biases)
                .into_executor()
                .with_profiling(),
        ),
        (
            "mpd-int8-prof",
            QuantizedMlp::quantize(&comp, &weights, &biases, &Calibration::unit_range(3))
                .map_err(anyhow::Error::msg)?
                .into_executor()
                .with_profiling(),
        ),
        ("conv-f32", PackedConvNet::build(&conv_comp, &cparams)?.into_executor()),
        ("tinyresnet-f32", PackedConvNet::build(&res_comp, &rparams)?.into_executor()),
        ("alexnet-lite-f32", PackedConvNet::build(&alex_comp, &aparams)?.into_executor()),
    ];
    let batch = 4;
    for (name, exec) in execs {
        let x: Vec<f32> = (0..batch * exec.in_dim()).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = vec![0.0f32; batch * exec.out_dim()];
        let mut scratch = ScratchArena::for_plan(exec.plan(), batch);
        // Two warm-up calls (the first may still touch lazily-sized paths).
        exec.run_into(&x, batch, &mut out, &mut scratch);
        exec.run_into(&x, batch, &mut out, &mut scratch);
        // Allocate the small-batch output *before* the measured windows so
        // both windows contain run_into calls only.
        let mut out1 = vec![0.0f32; exec.out_dim()];
        let before = ALLOC_COUNT.load(Ordering::Relaxed);
        for _ in 0..100 {
            exec.run_into(&x, batch, &mut out, &mut scratch);
        }
        // Smaller batches reuse the same arena without allocating either.
        let before_small = ALLOC_COUNT.load(Ordering::Relaxed);
        for _ in 0..10 {
            exec.run_into(&x[..exec.in_dim()], 1, &mut out1, &mut scratch);
        }
        let after = ALLOC_COUNT.load(Ordering::Relaxed);
        anyhow::ensure!(
            before_small == before && after == before_small,
            "{name}: run_into allocated on the hot path \
             ({} allocs over 100 warm calls + {} over 10 small-batch calls)",
            before_small - before,
            after - before_small
        );
        if let Some(p) = exec.profile() {
            anyhow::ensure!(
                p.runs() >= 112,
                "{name}: profiling enabled but only {} runs recorded",
                p.runs()
            );
        }
        println!("OK: {name} run_into performed 0 allocations across 110 warmed calls");
    }
    Ok(())
}

/// Span recording must be allocation-free once the ring exists and the
/// thread has claimed its slot — both warmed below, exactly as a serving
/// thread warms them on its first request.
fn span_zero_alloc_check() -> anyhow::Result<()> {
    use std::time::Instant;
    mpdc::obs::span::init(256);
    // Warm-up: the first record claims this thread's ring slot.
    mpdc::obs::span::record("leak_warm", Instant::now());
    {
        let _g = mpdc::obs::span("leak_warm");
    }
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    for i in 0..1000u64 {
        mpdc::obs::span::record_raw("leak_span", i, 1);
        let _g = mpdc::obs::span("leak_guard");
    }
    let after = ALLOC_COUNT.load(Ordering::Relaxed);
    anyhow::ensure!(
        after == before,
        "span recording allocated on the hot path ({} allocs over 2000 records)",
        after - before
    );
    // The records really landed (ring wraps at 256, totals keep counting).
    let snap = mpdc::obs::span::snapshot();
    let total: u64 = snap.threads.iter().map(|t| t.total).sum();
    anyhow::ensure!(total >= 2002, "span ring lost records: total {total}");
    println!("OK: span recording performed 0 allocations across 2000 records");
    Ok(())
}

/// Resident set size in MB (linux; 0.0 elsewhere so growth checks pass
/// trivially, mirroring `thread_count`).
fn rss_mb() -> f64 {
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).and_then(|v| v.parse::<f64>().ok()))
        .map(|pages| pages * 4096.0 / 1e6)
        .unwrap_or(0.0)
}

/// Live thread count of this process (linux; falls back to 0 elsewhere so
/// the delta assertions trivially pass).
fn thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

fn pool_lifecycle_check() -> anyhow::Result<()> {
    // Warm the global pool first so its (intentionally persistent) workers
    // are part of the baseline, not counted as a leak.
    mpdc::linalg::pool::global().run(4, |_| {});
    let baseline = thread_count();

    // 200 owned-pool lifecycles: every Drop must join its workers.
    for round in 0..200 {
        let pool = ThreadPool::new(2 + round % 6);
        let sum = std::sync::atomic::AtomicUsize::new(0);
        pool.run(17, |i| {
            sum.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
        });
        anyhow::ensure!(sum.into_inner() == 136, "pool dropped work on round {round}");
    }
    let after = thread_count();
    anyhow::ensure!(
        after <= baseline,
        "pool lifecycles leaked threads: {baseline} -> {after}"
    );
    println!("OK: 200 pool lifecycles, thread count {baseline} -> {after}");
    Ok(())
}

fn batcher_pool_check() -> anyhow::Result<()> {
    // A pooled packed LeNet served through the batcher: one persistent pool
    // reused across every batch; thread count and RSS must stay flat.
    let comp = MpdCompressor::new(SparsityPlan::lenet300(10), 7);
    let (weights, biases) = comp.random_masked_weights(7);
    let model = mpdc::compress::packed_model::PackedMlp::build(&comp, &weights, &biases);
    let pool = Arc::new(ThreadPool::new(4));
    let backend = PlanBackend::with_pool(model.into_executor(), pool.clone()).with_max_batch(16).warmed();

    let (h, join) = spawn(
        backend,
        BatcherConfig {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(200),
            deadline: std::time::Duration::ZERO,
            queue_depth: 256,
        },
    );
    // warmup then measure
    let x: Vec<f32> = (0..784).map(|i| (i as f32 * 0.01).sin()).collect();
    for _ in 0..50 {
        let _ = h.infer(x.clone()).expect("warmup infer");
    }
    let t0 = thread_count();
    let rss0 = rss_mb();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let h = h.clone();
            let x = x.clone();
            s.spawn(move || {
                for _ in 0..500 {
                    let y = h.infer(x.clone()).expect("infer");
                    assert_eq!(y.len(), 10);
                }
            });
        }
    });
    let grown = rss_mb() - rss0;
    let t1 = thread_count();
    anyhow::ensure!(t1 <= t0, "serving leaked threads: {t0} -> {t1}");
    anyhow::ensure!(grown < 32.0, "RSS grew {grown:.1} MB over 2000 pooled batches");
    println!(
        "OK: 2000 pooled batches, mean batch {:.2}, thread count {t0} -> {t1}, RSS +{grown:.1} MB",
        h.metrics.mean_batch_size()
    );
    drop(h);
    join.join().expect("batcher worker join");
    drop(pool);
    Ok(())
}

fn pjrt_check() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("SKIP pjrt check: artifacts not built");
        return Ok(());
    }
    let eng = match Engine::cpu(Manifest::load(&dir).map_err(|e| anyhow::anyhow!(e))?) {
        Ok(e) => e,
        // Only the pjrt-less build may skip here: with the feature on and
        // artifacts present, a client-init failure is exactly the kind of
        // regression this gate exists to catch.
        Err(e) if !cfg!(feature = "pjrt") => {
            println!("SKIP pjrt check: {e}");
            return Ok(());
        }
        Err(e) => anyhow::bail!("engine init failed with pjrt enabled: {e}"),
    };
    let exec = eng.load("lenet_train_step_b50")?;
    let args: Vec<Value> = exec
        .meta
        .inputs
        .iter()
        .map(|s| match s.dtype {
            DType::F32 => Value::F32(vec![0.1; s.numel()], s.shape.clone()),
            DType::I32 => Value::I32(vec![1; s.numel()], s.shape.clone()),
        })
        .collect();
    // warmup (first call maps executable memory)
    for _ in 0..10 {
        std::hint::black_box(exec.run(&args)?);
    }
    let start = rss_mb();
    println!("start rss {start:.1} MB");
    for i in 0..200 {
        std::hint::black_box(exec.run(&args)?);
        if i % 50 == 49 {
            println!("iter {i}: rss {:.1} MB", rss_mb());
        }
    }
    let grown = rss_mb() - start;
    anyhow::ensure!(grown < 64.0, "RSS grew {grown:.1} MB over 200 steps — buffer leak regressed");
    println!("OK: RSS growth {grown:.1} MB over 200 steps");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // First, before anything spawns threads: the exact-count assertions.
    run_into_zero_alloc_check()?;
    span_zero_alloc_check()?;
    pool_lifecycle_check()?;
    batcher_pool_check()?;
    pjrt_check()?;
    Ok(())
}
