//! PJRT memory-leak regression check.
//!
//! The upstream `xla` crate leaked one device copy of every input argument
//! per `execute` call (xla_rs.cc `execute`: `buffer.release()` without a
//! matching delete) — ~2.4 MB/step for the LeNet train step, which OOM-killed
//! long sweeps like the Fig. 4(a) 100-mask run. We carry a patched crate in
//! `third_party/xla` (see Cargo.toml `[patch.crates-io]`); this binary runs
//! 200 train steps and fails if RSS grows by more than 64 MB.
//!
//! ```bash
//! cargo run --release --bin leak_test
//! ```

use mpdc::runtime::engine::{Engine, Value};
use mpdc::runtime::manifest::{default_artifact_dir, DType, Manifest};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").expect("statm");
    s.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() * 4096.0 / 1e6
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("SKIP: artifacts not built");
        return Ok(());
    }
    let eng = Engine::cpu(Manifest::load(&dir).map_err(|e| anyhow::anyhow!(e))?)?;
    let exec = eng.load("lenet_train_step_b50")?;
    let args: Vec<Value> = exec
        .meta
        .inputs
        .iter()
        .map(|s| match s.dtype {
            DType::F32 => Value::F32(vec![0.1; s.numel()], s.shape.clone()),
            DType::I32 => Value::I32(vec![1; s.numel()], s.shape.clone()),
        })
        .collect();
    // warmup (first call maps executable memory)
    for _ in 0..10 {
        std::hint::black_box(exec.run(&args)?);
    }
    let start = rss_mb();
    println!("start rss {start:.1} MB");
    for i in 0..200 {
        std::hint::black_box(exec.run(&args)?);
        if i % 50 == 49 {
            println!("iter {i}: rss {:.1} MB", rss_mb());
        }
    }
    let grown = rss_mb() - start;
    anyhow::ensure!(grown < 64.0, "RSS grew {grown:.1} MB over 200 steps — buffer leak regressed");
    println!("OK: RSS growth {grown:.1} MB over 200 steps");
    Ok(())
}
