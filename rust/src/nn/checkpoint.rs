//! Binary checkpoint format for trained models ("MPDC" format v1).
//!
//! Layout (little-endian):
//! ```text
//!   magic   b"MPDC"          4 bytes
//!   version u32              currently 1
//!   ntensor u32
//!   repeat ntensor times:
//!     name_len u32, name utf-8 bytes
//!     ndim u32, dims u64 × ndim
//!     data f32 × prod(dims)
//!   crc32 u32                over everything before this field
//! ```
//! The trailing CRC (see `util::crc32`) catches truncation/corruption — a
//! checkpoint that loads is bit-exact.

use crate::util::crc32::Crc32;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MPDC";
const VERSION: u32 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    Corrupt(String),
    CrcMismatch { stored: u32, computed: u32 },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io error: {e}"),
            CheckpointError::BadMagic => write!(f, "bad magic (not an MPDC checkpoint)"),
            CheckpointError::BadVersion(v) => write!(f, "unsupported version {v}"),
            CheckpointError::Corrupt(s) => write!(f, "corrupt checkpoint: {s}"),
            CheckpointError::CrcMismatch { stored, computed } => {
                write!(f, "crc mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// A named tensor in a checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Save named tensors to `path` (parents created).
pub fn save(path: &Path, tensors: &[NamedTensor]) -> Result<(), CheckpointError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        let numel: usize = t.shape.iter().product();
        assert_eq!(t.data.len(), numel, "tensor {} shape/data mismatch", t.name);
        buf.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(t.name.as_bytes());
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut crc = Crc32::new();
    crc.update(&buf);
    buf.extend_from_slice(&crc.finish().to_le_bytes());
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?; // atomic publish
    Ok(())
}

/// Load all tensors from `path`, verifying the CRC.
pub fn load(path: &Path) -> Result<Vec<NamedTensor>, CheckpointError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < 16 {
        return Err(CheckpointError::Corrupt("file too small".into()));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let mut crc = Crc32::new();
    crc.update(body);
    let computed = crc.finish();
    if stored != computed {
        return Err(CheckpointError::CrcMismatch { stored, computed });
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], CheckpointError> {
        if *pos + n > body.len() {
            return Err(CheckpointError::Corrupt(format!("truncated at byte {pos}", pos = *pos)));
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if version != VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    let ntensor = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(ntensor);
    for _ in 0..ntensor {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if name_len > 4096 {
            return Err(CheckpointError::Corrupt(format!("absurd name length {name_len}")));
        }
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|e| CheckpointError::Corrupt(format!("bad name utf8: {e}")))?;
        let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if ndim > 16 {
            return Err(CheckpointError::Corrupt(format!("absurd ndim {ndim}")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = take(&mut pos, numel * 4)?;
        let data: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        out.push(NamedTensor { name, shape, data });
    }
    if pos != body.len() {
        return Err(CheckpointError::Corrupt("trailing bytes".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mpdc_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let dir = tmpdir();
        let path = dir.join("a.mpdc");
        let tensors = vec![
            NamedTensor { name: "fc0.w".into(), shape: vec![3, 4], data: (0..12).map(|i| i as f32).collect() },
            NamedTensor { name: "fc0.b".into(), shape: vec![3], data: vec![0.1, -0.2, 0.3] },
            NamedTensor { name: "empty".into(), shape: vec![0], data: vec![] },
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let dir = tmpdir();
        let path = dir.join("b.mpdc");
        save(&path, &[NamedTensor { name: "t".into(), shape: vec![2], data: vec![1.0, 2.0] }]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xFF; // flip a data byte
        std::fs::write(&path, &bytes).unwrap();
        match load(&path) {
            Err(CheckpointError::CrcMismatch { .. }) => {}
            other => panic!("expected CRC mismatch, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = tmpdir();
        let path = dir.join("c.mpdc");
        save(&path, &[NamedTensor { name: "t".into(), shape: vec![8], data: vec![1.0; 8] }]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = tmpdir();
        let path = dir.join("d.mpdc");
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 12]);
        let mut crc = Crc32::new();
        crc.update(&buf);
        let c = crc.finish();
        buf.extend_from_slice(&c.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        match load(&path) {
            Err(CheckpointError::BadMagic) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
