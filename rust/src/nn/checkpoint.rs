//! Binary checkpoint format for trained models ("MPDC" format, versions 1–2).
//!
//! Layout (little-endian):
//! ```text
//!   magic   b"MPDC"          4 bytes
//!   version u32              1 (f32-only) or 2 (per-tensor dtype tag)
//!   ntensor u32
//!   repeat ntensor times:
//!     name_len u32, name utf-8 bytes
//!     ndim u32, dims u64 × ndim
//!     dtype u8                 — version 2 only (0 = f32, 1 = i8)
//!     data elem × prod(dims)   — elem is f32 (v1, or v2 dtype 0) or i8
//!   crc32 u32                over everything before this field
//! ```
//! The trailing CRC (see `util::crc32`) catches truncation/corruption — a
//! checkpoint that loads is bit-exact.
//!
//! **Version policy.** [`save`] emits version 1 when every tensor is f32 —
//! bit-identical to what pre-quantization builds wrote, so old readers and
//! old files keep working — and version 2 as soon as any tensor carries a
//! non-f32 dtype. [`load`] reads both. Quantized models (`quant::QuantizedMlp`)
//! store i8 weight tensors next to f32 scale sidecars by naming convention
//! (`fc0.wq` + `fc0.wq.scale`); the container itself only knows dtypes.
//!
//! **Hostile-input hardening.** Before a tensor's data buffer is ever
//! allocated, `prod(dims) × elem_size` is computed with overflow checks and
//! validated against the bytes actually remaining in the file, so a corrupt
//! or truncated header fails with [`CheckpointError::Corrupt`] instead of
//! attempting a multi-GB allocation.

use crate::util::crc32::Crc32;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MPDC";
/// Original all-f32 format.
const VERSION_V1: u32 = 1;
/// Adds a one-byte dtype tag per tensor (i8 quantized weights + f32 sidecars).
const VERSION_V2: u32 = 2;

const DTYPE_F32: u8 = 0;
const DTYPE_I8: u8 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    Corrupt(String),
    CrcMismatch { stored: u32, computed: u32 },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io error: {e}"),
            CheckpointError::BadMagic => write!(f, "bad magic (not an MPDC checkpoint)"),
            CheckpointError::BadVersion(v) => write!(f, "unsupported version {v}"),
            CheckpointError::Corrupt(s) => write!(f, "corrupt checkpoint: {s}"),
            CheckpointError::CrcMismatch { stored, computed } => {
                write!(f, "crc mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// Typed tensor payload. `F32` round-trips through format v1; any other
/// dtype forces the container to version 2.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes per element of this dtype.
    pub fn elem_size(&self) -> usize {
        match self {
            TensorData::F32(_) => 4,
            TensorData::I8(_) => 1,
        }
    }

    fn dtype_tag(&self) -> u8 {
        match self {
            TensorData::F32(_) => DTYPE_F32,
            TensorData::I8(_) => DTYPE_I8,
        }
    }
}

/// A named tensor in a checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl NamedTensor {
    pub fn f32(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        Self { name: name.into(), shape, data: TensorData::F32(data) }
    }

    pub fn i8(name: impl Into<String>, shape: Vec<usize>, data: Vec<i8>) -> Self {
        Self { name: name.into(), shape, data: TensorData::I8(data) }
    }

    /// Borrow the payload as f32 (None when the tensor holds another dtype).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow the payload as i8 (None when the tensor holds another dtype).
    pub fn as_i8(&self) -> Option<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Some(v),
            _ => None,
        }
    }

    /// Take the payload as f32 (None when the tensor holds another dtype).
    pub fn into_f32(self) -> Option<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// Save named tensors to `path` (parents created). Emits format v1 when all
/// tensors are f32 (byte-compatible with old files), v2 otherwise.
pub fn save(path: &Path, tensors: &[NamedTensor]) -> Result<(), CheckpointError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let version =
        if tensors.iter().all(|t| matches!(t.data, TensorData::F32(_))) { VERSION_V1 } else { VERSION_V2 };
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        let numel: usize = t.shape.iter().product();
        assert_eq!(t.data.len(), numel, "tensor {} shape/data mismatch", t.name);
        buf.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(t.name.as_bytes());
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        if version == VERSION_V2 {
            buf.push(t.data.dtype_tag());
        }
        match &t.data {
            TensorData::F32(vals) => {
                for &v in vals {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            TensorData::I8(vals) => {
                for &v in vals {
                    buf.push(v as u8);
                }
            }
        }
    }
    let mut crc = Crc32::new();
    crc.update(&buf);
    buf.extend_from_slice(&crc.finish().to_le_bytes());
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?; // atomic publish
    Ok(())
}

/// Load all tensors from `path`, verifying the CRC. Accepts format v1
/// (implicit f32) and v2 (per-tensor dtype tags).
pub fn load(path: &Path) -> Result<Vec<NamedTensor>, CheckpointError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < 16 {
        return Err(CheckpointError::Corrupt("file too small".into()));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let mut crc = Crc32::new();
    crc.update(body);
    let computed = crc.finish();
    if stored != computed {
        return Err(CheckpointError::CrcMismatch { stored, computed });
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], CheckpointError> {
        if n > body.len() - *pos {
            return Err(CheckpointError::Corrupt(format!("truncated at byte {pos}", pos = *pos)));
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if version != VERSION_V1 && version != VERSION_V2 {
        return Err(CheckpointError::BadVersion(version));
    }
    let ntensor = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(ntensor.min(4096));
    for _ in 0..ntensor {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if name_len > 4096 {
            return Err(CheckpointError::Corrupt(format!("absurd name length {name_len}")));
        }
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|e| CheckpointError::Corrupt(format!("bad name utf8: {e}")))?;
        let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if ndim > 16 {
            return Err(CheckpointError::Corrupt(format!("absurd ndim {ndim}")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        let dtype = if version == VERSION_V2 {
            match take(&mut pos, 1)?[0] {
                DTYPE_F32 => DTYPE_F32,
                DTYPE_I8 => DTYPE_I8,
                other => {
                    return Err(CheckpointError::Corrupt(format!(
                        "tensor {name}: unknown dtype tag {other}"
                    )))
                }
            }
        } else {
            DTYPE_F32
        };
        // Validate the claimed payload size BEFORE allocating anything for
        // it: the element count must not overflow, and the byte count must
        // fit in what actually remains of the file — a corrupt header
        // otherwise asks for a multi-GB buffer.
        let elem_size = if dtype == DTYPE_F32 { 4usize } else { 1 };
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| CheckpointError::Corrupt(format!("tensor {name}: dims product overflows")))?;
        let nbytes = numel
            .checked_mul(elem_size)
            .ok_or_else(|| CheckpointError::Corrupt(format!("tensor {name}: byte size overflows")))?;
        if nbytes > body.len() - pos {
            return Err(CheckpointError::Corrupt(format!(
                "tensor {name}: {nbytes} data bytes claimed but only {} remain",
                body.len() - pos
            )));
        }
        let raw = take(&mut pos, nbytes)?;
        let data = match dtype {
            DTYPE_F32 => TensorData::F32(
                raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            _ => TensorData::I8(raw.iter().map(|&b| b as i8).collect()),
        };
        out.push(NamedTensor { name, shape, data });
    }
    if pos != body.len() {
        return Err(CheckpointError::Corrupt("trailing bytes".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mpdc_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let dir = tmpdir();
        let path = dir.join("a.mpdc");
        let tensors = vec![
            NamedTensor::f32("fc0.w", vec![3, 4], (0..12).map(|i| i as f32).collect()),
            NamedTensor::f32("fc0.b", vec![3], vec![0.1, -0.2, 0.3]),
            NamedTensor::f32("empty", vec![0], vec![]),
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_f32_saves_as_v1() {
        let dir = tmpdir();
        let path = dir.join("v1.mpdc");
        save(&path, &[NamedTensor::f32("t", vec![2], vec![1.0, 2.0])]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_V1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn i8_tensor_forces_v2_and_roundtrips() {
        let dir = tmpdir();
        let path = dir.join("v2.mpdc");
        let tensors = vec![
            NamedTensor::i8("fc0.wq", vec![2, 3], vec![-128, -1, 0, 1, 42, 127]),
            NamedTensor::f32("fc0.wq.scale", vec![2], vec![0.01, 0.02]),
        ];
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_V2);
        let back = load(&path).unwrap();
        assert_eq!(back, tensors);
        assert_eq!(back[0].as_i8().unwrap(), &[-128, -1, 0, 1, 42, 127]);
        assert!(back[0].as_f32().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let dir = tmpdir();
        let path = dir.join("b.mpdc");
        save(&path, &[NamedTensor::f32("t", vec![2], vec![1.0, 2.0])]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xFF; // flip a data byte
        std::fs::write(&path, &bytes).unwrap();
        match load(&path) {
            Err(CheckpointError::CrcMismatch { .. }) => {}
            other => panic!("expected CRC mismatch, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = tmpdir();
        let path = dir.join("c.mpdc");
        save(&path, &[NamedTensor::f32("t", vec![8], vec![1.0; 8])]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = tmpdir();
        let path = dir.join("d.mpdc");
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 12]);
        let mut crc = Crc32::new();
        crc.update(&buf);
        let c = crc.finish();
        buf.extend_from_slice(&c.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        match load(&path) {
            Err(CheckpointError::BadMagic) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
