//! Native neural-network engine: layers, MLP/conv models, checkpointing.
pub mod checkpoint;
pub mod conv;
pub mod convnet;
pub mod layer;
pub mod mlp;

pub use convnet::{ConvNet, ConvNetSpec, ConvStageSpec, PoolKind};
pub use layer::{accuracy, softmax, softmax_xent, topk_accuracy, FcVariant, Linear, Relu};
pub use mlp::Mlp;
