//! Direct 2-D convolution + max-pool (NCHW) for the paper's conv models
//! (Deep MNIST, CIFAR-10 net, AlexNet front-end).
//!
//! The paper itself only masks FC layers, but a `Conv2d` *is* an FC layer
//! over receptive-field patches: its weights flatten to the
//! `(out_c × in_c·kh·kw)` filter matrix, so MPD masks apply to it exactly as
//! to `nn::layer::Linear` (PERMDNN makes the same move for permuted sparsity
//! on conv layers). [`Conv2d::with_mask`] attaches a mask over the filter
//! matrix; [`Conv2d::sgd_step`] re-applies it after every update, the
//! in-training-masking contract of Algorithm 1. Compressed inference lowers
//! through `linalg::im2col` onto the packed block-diagonal engine; this
//! direct loop stays the training substrate and the correctness oracle.
//!
//! **Accumulation-order contract:** the direct loop sums taps in
//! `ic → ky → kx` order (ascending filter-matrix column), skipping padded
//! taps, and adds the bias *after* the reduction — the same association the
//! packed engine's fused epilogue uses (`acc + bias`), which is what makes
//! the im2col-lowered forward bit-identical to this loop (see
//! `linalg::im2col` and `tests/conv.rs`).

use crate::mask::mask::MpdMask;
use crate::mask::prng::Xoshiro256pp;
use crate::nn::layer::he_init;

/// `same`-or-`valid` 2-D convolution layer, NCHW activations,
/// weights `[out_c, in_c, kh, kw]` (equivalently the row-major
/// `(out_c × in_c·kh·kw)` filter matrix), optionally under an MPD mask on
/// that filter matrix.
pub struct Conv2d {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub out_c: usize,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    /// Channel groups (AlexNet-style): group `g` convolves input channels
    /// `[g·in_c/groups, (g+1)·in_c/groups)` into output channels
    /// `[g·out_c/groups, (g+1)·out_c/groups)`. Storage stays the full
    /// `(out_c × in_c·kh·kw)` filter matrix with off-group weights pinned at
    /// `0.0` (init zero, never touched by backward), which is exactly the
    /// block-diagonal filter matrix the packed lowering consumes.
    pub groups: usize,
    /// Optional MPD mask over the `(out_c × in_c·kh·kw)` filter matrix.
    pub mask: Option<MpdMask>,
    x_cache: Vec<f32>,
    in_hw: (usize, usize),
    batch_cache: usize,
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

impl Conv2d {
    pub fn new(out_c: usize, in_c: usize, k: usize, stride: usize, pad: usize, rng: &mut Xoshiro256pp) -> Self {
        Self::new_grouped(out_c, in_c, k, stride, pad, 1, rng)
    }

    /// Grouped constructor. `out_c` and `in_c` must both divide by `groups`.
    /// He-init uses the *per-group* fan-in (`in_c/groups·k²`), scattered into
    /// the full filter matrix so off-group entries are exactly `0.0`.
    pub fn new_grouped(
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        assert!(groups >= 1 && out_c % groups == 0 && in_c % groups == 0, "conv groups must divide channels");
        let (icg, ocg) = (in_c / groups, out_c / groups);
        let w = if groups == 1 {
            he_init(out_c, in_c * k * k, rng)
        } else {
            let dense = he_init(out_c, icg * k * k, rng);
            let mut w = vec![0.0f32; out_c * in_c * k * k];
            for oc in 0..out_c {
                let g = oc / ocg;
                for ic in 0..icg {
                    let src = &dense[(oc * icg + ic) * k * k..][..k * k];
                    let dst = &mut w[(oc * in_c + g * icg + ic) * k * k..][..k * k];
                    dst.copy_from_slice(src);
                }
            }
            w
        };
        Self {
            w,
            b: vec![0.0; out_c],
            out_c,
            in_c,
            kh: k,
            kw: k,
            stride,
            pad,
            groups,
            mask: None,
            x_cache: Vec::new(),
            in_hw: (0, 0),
            batch_cache: 0,
            dw: vec![0.0; out_c * in_c * k * k],
            db: vec![0.0; out_c],
        }
    }

    /// Attach an MPD mask over the filter matrix (and immediately apply it),
    /// mirroring [`crate::nn::layer::Linear::with_mask`].
    pub fn with_mask(mut self, mask: MpdMask) -> Self {
        assert_eq!(mask.rows(), self.out_c, "mask rows must equal out channels");
        assert_eq!(mask.cols(), self.in_c * self.kh * self.kw, "mask cols must equal filter-matrix cols");
        mask.apply_inplace(&mut self.w);
        self.mask = Some(mask);
        self
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// Forward: direct convolution.
    pub fn forward(&mut self, x: &[f32], batch: usize, h: usize, w: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_c * h * w);
        self.x_cache = x.to_vec();
        self.in_hw = (h, w);
        self.batch_cache = batch;
        let (oh, ow) = self.out_hw(h, w);
        let (icg, ocg) = (self.in_c / self.groups, self.out_c / self.groups);
        let mut y = vec![0.0f32; batch * self.out_c * oh * ow];
        for bi in 0..batch {
            for oc in 0..self.out_c {
                let bias = self.b[oc];
                // Only this output channel's group of input channels; the
                // skipped taps carry exactly-zero weights, so the restricted
                // loop is bit-identical to summing the full filter row.
                let ic0 = (oc / ocg) * icg;
                for oy in 0..oh {
                    for ox in 0..ow {
                        // Products first, bias last — the packed engine's
                        // epilogue association, so the im2col lowering can be
                        // bit-identical to this loop.
                        let mut acc = 0.0f32;
                        for ic in ic0..ic0 + icg {
                            for ky in 0..self.kh {
                                let iy = oy * self.stride + ky;
                                if iy < self.pad || iy - self.pad >= h {
                                    continue;
                                }
                                let iy = iy - self.pad;
                                let xrow = &x[((bi * self.in_c + ic) * h + iy) * w..];
                                let wrow = &self.w[((oc * self.in_c + ic) * self.kh + ky) * self.kw..];
                                for kx in 0..self.kw {
                                    let ix = ox * self.stride + kx;
                                    if ix < self.pad || ix - self.pad >= w {
                                        continue;
                                    }
                                    acc += xrow[ix - self.pad] * wrow[kx];
                                }
                            }
                        }
                        y[((bi * self.out_c + oc) * oh + oy) * ow + ox] = acc + bias;
                    }
                }
            }
        }
        y
    }

    /// Backward: accumulate dW/db, return dX.
    pub fn backward(&mut self, dy: &[f32]) -> Vec<f32> {
        let (h, w) = self.in_hw;
        let batch = self.batch_cache;
        let (oh, ow) = self.out_hw(h, w);
        assert_eq!(dy.len(), batch * self.out_c * oh * ow);
        let (icg, ocg) = (self.in_c / self.groups, self.out_c / self.groups);
        let mut dx = vec![0.0f32; batch * self.in_c * h * w];
        for bi in 0..batch {
            for oc in 0..self.out_c {
                // Off-group weights never receive gradient, so they stay at
                // their exact-zero init across training.
                let ic0 = (oc / ocg) * icg;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let g = dy[((bi * self.out_c + oc) * oh + oy) * ow + ox];
                        if g == 0.0 {
                            continue;
                        }
                        self.db[oc] += g;
                        for ic in ic0..ic0 + icg {
                            for ky in 0..self.kh {
                                let iy = oy * self.stride + ky;
                                if iy < self.pad || iy - self.pad >= h {
                                    continue;
                                }
                                let iy = iy - self.pad;
                                for kx in 0..self.kw {
                                    let ix = ox * self.stride + kx;
                                    if ix < self.pad || ix - self.pad >= w {
                                        continue;
                                    }
                                    let ix = ix - self.pad;
                                    let xi = ((bi * self.in_c + ic) * h + iy) * w + ix;
                                    let wi = ((oc * self.in_c + ic) * self.kh + ky) * self.kw + kx;
                                    self.dw[wi] += g * self.x_cache[xi];
                                    dx[xi] += g * self.w[wi];
                                }
                            }
                        }
                    }
                }
            }
        }
        dx
    }

    /// SGD step; re-applies the filter-matrix mask to the *updated* weights,
    /// the same in-training-masking rule `Linear::sgd_step` follows.
    pub fn sgd_step(&mut self, lr: f32) {
        for (w, g) in self.w.iter_mut().zip(&self.dw) {
            *w -= lr * g;
        }
        for (b, g) in self.b.iter_mut().zip(&self.db) {
            *b -= lr * g;
        }
        if let Some(mask) = &self.mask {
            mask.apply_inplace(&mut self.w);
        }
        self.zero_grad();
    }

    pub fn zero_grad(&mut self) {
        self.dw.iter_mut().for_each(|v| *v = 0.0);
        self.db.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Logical parameter count: a grouped conv stores the full filter matrix
    /// but only `out_c·(in_c/groups)·k²` weights are live — the dense
    /// baseline a compression ratio is measured against.
    pub fn param_count(&self) -> usize {
        self.out_c * (self.in_c / self.groups) * self.kh * self.kw + self.b.len()
    }

    /// Surviving parameter count after masking (weights on the mask + biases).
    pub fn effective_param_count(&self) -> usize {
        match &self.mask {
            Some(m) => m.nnz() + self.b.len(),
            None => self.param_count(),
        }
    }
}

/// 2×2-style max pooling, NCHW.
pub struct MaxPool2d {
    pub k: usize,
    pub stride: usize,
    argmax: Vec<usize>,
    in_shape: (usize, usize, usize, usize),
}

impl MaxPool2d {
    pub fn new(k: usize, stride: usize) -> Self {
        Self { k, stride, argmax: Vec::new(), in_shape: (0, 0, 0, 0) }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h - self.k) / self.stride + 1, (w - self.k) / self.stride + 1)
    }

    pub fn forward(&mut self, x: &[f32], batch: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * c * h * w);
        self.in_shape = (batch, c, h, w);
        let (oh, ow) = self.out_hw(h, w);
        let mut y = vec![0.0f32; batch * c * oh * ow];
        self.argmax = vec![0usize; y.len()];
        for bc in 0..batch * c {
            let xp = &x[bc * h * w..(bc + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut besti = 0usize;
                    for ky in 0..self.k {
                        for kx in 0..self.k {
                            let iy = oy * self.stride + ky;
                            let ix = ox * self.stride + kx;
                            let v = xp[iy * w + ix];
                            if v > best {
                                best = v;
                                besti = iy * w + ix;
                            }
                        }
                    }
                    let oi = (bc * oh + oy) * ow + ox;
                    y[oi] = best;
                    self.argmax[oi] = bc * h * w + besti;
                }
            }
        }
        y
    }

    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        let (batch, c, h, w) = self.in_shape;
        assert_eq!(dy.len(), self.argmax.len());
        let mut dx = vec![0.0f32; batch * c * h * w];
        for (oi, &ii) in self.argmax.iter().enumerate() {
            dx[ii] += dy[oi];
        }
        dx
    }
}

/// Average pooling, NCHW. Global average pooling is the `k == h == w` case
/// (one value per channel) — the ResNet-style head reducer.
///
/// **Exactness contract:** each window accumulates taps in ascending
/// `ky → kx` order from `+0.0`, then divides by `(k·k)` as an f32 — the
/// identical association `linalg::im2col::avgpool_nchw` uses, so the lowered
/// inference pool is bit-identical to this trainer pool.
pub struct AvgPool2d {
    pub k: usize,
    pub stride: usize,
    in_shape: (usize, usize, usize, usize),
}

impl AvgPool2d {
    pub fn new(k: usize, stride: usize) -> Self {
        Self { k, stride, in_shape: (0, 0, 0, 0) }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h - self.k) / self.stride + 1, (w - self.k) / self.stride + 1)
    }

    pub fn forward(&mut self, x: &[f32], batch: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * c * h * w);
        self.in_shape = (batch, c, h, w);
        let (oh, ow) = self.out_hw(h, w);
        let area = (self.k * self.k) as f32;
        let mut y = vec![0.0f32; batch * c * oh * ow];
        for bc in 0..batch * c {
            let xp = &x[bc * h * w..(bc + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ky in 0..self.k {
                        for kx in 0..self.k {
                            acc += xp[(oy * self.stride + ky) * w + (ox * self.stride + kx)];
                        }
                    }
                    y[(bc * oh + oy) * ow + ox] = acc / area;
                }
            }
        }
        y
    }

    /// Mean is linear: every tap of a window receives `dy / k²`.
    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        let (batch, c, h, w) = self.in_shape;
        let (oh, ow) = self.out_hw(h, w);
        assert_eq!(dy.len(), batch * c * oh * ow);
        let area = (self.k * self.k) as f32;
        let mut dx = vec![0.0f32; batch * c * h * w];
        for bc in 0..batch * c {
            let dxp = &mut dx[bc * h * w..(bc + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dy[(bc * oh + oy) * ow + ox] / area;
                    for ky in 0..self.k {
                        for kx in 0..self.k {
                            dxp[(oy * self.stride + ky) * w + (ox * self.stride + kx)] += g;
                        }
                    }
                }
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(seed)
    }

    #[test]
    fn conv_identity_kernel() {
        let mut r = rng(1);
        let mut conv = Conv2d::new(1, 1, 1, 1, 0, &mut r);
        conv.w = vec![1.0];
        conv.b = vec![0.0];
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let y = conv.forward(&x, 1, 3, 3);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_output_shape_with_pad_stride() {
        let mut r = rng(2);
        let conv = Conv2d::new(4, 3, 3, 2, 1, &mut r);
        assert_eq!(conv.out_hw(28, 28), (14, 14));
        let conv2 = Conv2d::new(4, 3, 5, 1, 0, &mut r);
        assert_eq!(conv2.out_hw(28, 28), (24, 24));
    }

    #[test]
    fn conv_known_values() {
        // 2×2 input, 2×2 kernel of ones, valid → sum of inputs
        let mut r = rng(3);
        let mut conv = Conv2d::new(1, 1, 2, 1, 0, &mut r);
        conv.w = vec![1.0; 4];
        conv.b = vec![0.5];
        let y = conv.forward(&[1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        assert_eq!(y, vec![10.5]);
    }

    #[test]
    fn conv_gradcheck() {
        let mut r = rng(4);
        let mut conv = Conv2d::new(2, 1, 3, 1, 1, &mut r);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let loss_of = |conv: &mut Conv2d, x: &[f32]| -> f32 {
            let y = conv.forward(x, 1, 4, 4);
            y.iter().map(|v| v * v).sum::<f32>() * 0.5
        };
        let y = conv.forward(&x, 1, 4, 4);
        conv.zero_grad();
        let dx = conv.backward(&y); // dL/dy = y for L = ½‖y‖²
        let eps = 1e-3f32;
        for &idx in &[0usize, 7, 17] {
            let orig = conv.w[idx];
            conv.w[idx] = orig + eps;
            let lp = loss_of(&mut conv, &x);
            conv.w[idx] = orig - eps;
            let lm = loss_of(&mut conv, &x);
            conv.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((conv.dw[idx] - num).abs() < 2e-2, "dw[{idx}] {} vs {num}", conv.dw[idx]);
        }
        // dx check at one position
        let mut x2 = x.clone();
        let idx = 5;
        x2[idx] += eps;
        let lp = loss_of(&mut conv, &x2);
        x2[idx] -= 2.0 * eps;
        let lm = loss_of(&mut conv, &x2);
        let num = (lp - lm) / (2.0 * eps);
        assert!((dx[idx] - num).abs() < 2e-2, "dx[{idx}] {} vs {num}", dx[idx]);
    }

    #[test]
    fn masked_conv_keeps_filter_matrix_on_mask() {
        let mut r = rng(6);
        // filter matrix is 4 × (2·3·3) = 4×18; mask it with 2 blocks
        let mask = MpdMask::generate(4, 18, 2, &mut r);
        let dense_mask = mask.to_dense();
        let mut conv = Conv2d::new(4, 2, 3, 1, 1, &mut r).with_mask(mask);
        for (i, &m) in dense_mask.iter().enumerate() {
            if m == 0.0 {
                assert_eq!(conv.w[i], 0.0, "init leaked off-mask");
            }
        }
        // one training step: gradients flow, off-mask weights stay zero
        let x: Vec<f32> = (0..2 * 4 * 4).map(|i| (i as f32 * 0.23).sin()).collect();
        let y = conv.forward(&x, 1, 4, 4);
        conv.backward(&y);
        conv.sgd_step(0.05);
        for (i, &m) in dense_mask.iter().enumerate() {
            if m == 0.0 {
                assert_eq!(conv.w[i], 0.0, "weight {i} leaked off-mask after sgd");
            }
        }
        assert_eq!(conv.effective_param_count(), conv.mask.as_ref().unwrap().nnz() + 4);
    }

    #[test]
    fn grouped_conv_structure_and_gradcheck() {
        let mut r = rng(7);
        // 4 out, 4 in, 2 groups: group 0 = out{0,1}×in{0,1}, group 1 = out{2,3}×in{2,3}
        let mut conv = Conv2d::new_grouped(4, 4, 3, 1, 1, 2, &mut r);
        let kk = 9;
        for oc in 0..4 {
            for ic in 0..4 {
                let on_group = (oc / 2) == (ic / 2);
                let blk = &conv.w[(oc * 4 + ic) * kk..][..kk];
                if on_group {
                    assert!(blk.iter().any(|&v| v != 0.0), "on-group block ({oc},{ic}) all zero");
                } else {
                    assert!(blk.iter().all(|&v| v == 0.0), "off-group block ({oc},{ic}) leaked");
                }
            }
        }
        assert_eq!(conv.param_count(), 4 * 2 * 9 + 4);
        let x: Vec<f32> = (0..4 * 4 * 4).map(|i| (i as f32 * 0.19).sin()).collect();
        let loss_of = |conv: &mut Conv2d, x: &[f32]| -> f32 {
            let y = conv.forward(x, 1, 4, 4);
            y.iter().map(|v| v * v).sum::<f32>() * 0.5
        };
        let y = conv.forward(&x, 1, 4, 4);
        conv.zero_grad();
        conv.backward(&y);
        let eps = 1e-3f32;
        // an on-group weight: numeric gradient matches
        let idx = (2usize * 4 + 3) * kk + 4; // oc=2, ic=3 → on-group (both group 1)
        let orig = conv.w[idx];
        conv.w[idx] = orig + eps;
        let lp = loss_of(&mut conv, &x);
        conv.w[idx] = orig - eps;
        let lm = loss_of(&mut conv, &x);
        conv.w[idx] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((conv.dw[idx] - num).abs() < 2e-2, "dw[{idx}] {} vs {num}", conv.dw[idx]);
        // off-group weights never accumulate gradient and survive sgd at zero
        let off = (0usize * 4 + 3) * kk + 1; // oc=0, ic=3 → off-group
        assert_eq!(conv.dw[off], 0.0);
        conv.sgd_step(0.05);
        assert_eq!(conv.w[off], 0.0);
    }

    #[test]
    fn grouped_conv_matches_per_group_dense_convs() {
        // A g=2 conv equals two independent dense convs over channel halves.
        let mut r = rng(8);
        let conv_g = Conv2d::new_grouped(4, 2, 3, 2, 1, 2, &mut r);
        let x: Vec<f32> = (0..2 * 5 * 5).map(|i| (i as f32 * 0.31).cos()).collect();
        let mut halves = Vec::new();
        for g in 0..2 {
            let mut sub = Conv2d::new(2, 1, 3, 2, 1, &mut r);
            for oc in 0..2 {
                let src = &conv_g.w[((g * 2 + oc) * 2 + g) * 9..][..9];
                sub.w[oc * 9..(oc + 1) * 9].copy_from_slice(src);
                sub.b[oc] = conv_g.b[g * 2 + oc];
            }
            let xg = &x[g * 25..(g + 1) * 25];
            halves.push(sub.forward(xg, 1, 5, 5));
        }
        let mut conv_g = conv_g;
        let y = conv_g.forward(&x, 1, 5, 5);
        let want: Vec<f32> = halves.concat();
        assert_eq!(y, want);
    }

    #[test]
    fn avgpool_forward_backward() {
        let mut ap = AvgPool2d::new(2, 2);
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0, 5.0, 6.0,
            3.0, 4.0, 7.0, 8.0,
            0.0, 0.0, 4.0, 0.0,
            0.0, 8.0, 0.0, 0.0,
        ];
        let y = ap.forward(&x, 1, 1, 4, 4);
        assert_eq!(y, vec![2.5, 6.5, 2.0, 1.0]);
        let dx = ap.backward(&[4.0, 4.0, 4.0, 4.0]);
        // every tap of each window gets dy/4
        assert!(dx.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn avgpool_global_is_channel_mean() {
        let mut ap = AvgPool2d::new(3, 1);
        let x: Vec<f32> = (0..18).map(|i| i as f32).collect(); // 2 ch × 3×3
        let y = ap.forward(&x, 1, 2, 3, 3);
        assert_eq!(y, vec![4.0, 13.0]);
    }

    #[test]
    fn maxpool_forward_backward() {
        let mut mp = MaxPool2d::new(2, 2);
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0, 5.0, 6.0,
            3.0, 4.0, 7.0, 8.0,
            0.0, 0.0, 1.0, 0.0,
            0.0, 9.0, 0.0, 0.0,
        ];
        let y = mp.forward(&x, 1, 1, 4, 4);
        assert_eq!(y, vec![4.0, 8.0, 9.0, 1.0]);
        let dx = mp.backward(&[1.0, 1.0, 1.0, 1.0]);
        // gradient lands only on the argmax positions
        assert_eq!(dx[5], 1.0); // the 4.0
        assert_eq!(dx[7], 1.0); // the 8.0
        assert_eq!(dx[13], 1.0); // the 9.0
        assert_eq!(dx[10], 1.0); // the 1.0
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }
}
