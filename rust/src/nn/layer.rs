//! Native NN layers: forward + backward for the layer types the paper's four
//! models need. The native engine serves three purposes: (1) a CPU baseline
//! trainer that cross-checks the JAX/AOT path, (2) the dense / CSR / packed
//! block-diagonal *inference* competitors for the §3.3 speedup study, and
//! (3) a dependency-free way to run the Fig. 4 hundred-mask sweep fast.
//!
//! Conventions: activations are row-major `[batch × features]` (or
//! `[batch, C, H, W]` for conv). A `Linear` stores `w: [out × in]`
//! (`d_{i+1} × d_i`, matching the paper's `W_i`), so forward is
//! `Y = X·Wᵀ + b`.

use crate::linalg::blockdiag_mm::BlockDiagMatrix;
use crate::linalg::csr::Csr;
use crate::linalg::gemm::{gemm, gemm_a_bt, gemm_at_b};
use crate::mask::mask::MpdMask;
use crate::mask::prng::Xoshiro256pp;

/// He-normal initialization for a `[out × in]` weight matrix.
pub fn he_init(out: usize, inp: usize, rng: &mut Xoshiro256pp) -> Vec<f32> {
    let std = (2.0 / inp as f64).sqrt();
    (0..out * inp).map(|_| (rng.next_normal() * std) as f32).collect()
}

/// Fully-connected layer with optional MPD mask (Algorithm 1: the mask is
/// re-applied after every weight update, so the gradient flow itself "molds"
/// the weights to the permuted block structure).
pub struct Linear {
    pub w: Vec<f32>, // [out × in]
    pub b: Vec<f32>, // [out]
    pub out_dim: usize,
    pub in_dim: usize,
    pub mask: Option<MpdMask>,
    // cached input for backward
    x_cache: Vec<f32>,
    batch_cache: usize,
    // gradients
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

impl Linear {
    pub fn new(out_dim: usize, in_dim: usize, rng: &mut Xoshiro256pp) -> Self {
        Self {
            w: he_init(out_dim, in_dim, rng),
            b: vec![0.0; out_dim],
            out_dim,
            in_dim,
            mask: None,
            x_cache: Vec::new(),
            batch_cache: 0,
            dw: vec![0.0; out_dim * in_dim],
            db: vec![0.0; out_dim],
        }
    }

    /// Attach an MPD mask (and immediately apply it — Algorithm 1 line 14).
    pub fn with_mask(mut self, mask: MpdMask) -> Self {
        assert_eq!(mask.rows(), self.out_dim);
        assert_eq!(mask.cols(), self.in_dim);
        mask.apply_inplace(&mut self.w);
        self.mask = Some(mask);
        self
    }

    /// `Y = X·Wᵀ + b`
    pub fn forward(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_dim);
        self.x_cache = x.to_vec();
        self.batch_cache = batch;
        let mut y = vec![0.0f32; batch * self.out_dim];
        for bi in 0..batch {
            y[bi * self.out_dim..(bi + 1) * self.out_dim].copy_from_slice(&self.b);
        }
        gemm_a_bt(x, &self.w, &mut y, batch, self.in_dim, self.out_dim);
        y
    }

    /// Backward: given dY, accumulate dW, db and return dX.
    /// dW = dYᵀ·X, db = Σ dY, dX = dY·W.
    pub fn backward(&mut self, dy: &[f32]) -> Vec<f32> {
        let batch = self.batch_cache;
        assert_eq!(dy.len(), batch * self.out_dim);
        // dW[out×in] += dYᵀ[out×batch]·X[batch×in]
        gemm_at_b(dy, &self.x_cache, &mut self.dw, self.out_dim, batch, self.in_dim);
        for bi in 0..batch {
            for o in 0..self.out_dim {
                self.db[o] += dy[bi * self.out_dim + o];
            }
        }
        // dX[batch×in] = dY[batch×out]·W[out×in]
        let mut dx = vec![0.0f32; batch * self.in_dim];
        gemm(dy, &self.w, &mut dx, batch, self.out_dim, self.in_dim);
        dx
    }

    /// SGD step; re-applies the mask to the *updated* weights, exactly as the
    /// paper specifies ("binary masks are applied only on the updated weights
    /// after the gradient descent calculation").
    pub fn sgd_step(&mut self, lr: f32) {
        for (w, g) in self.w.iter_mut().zip(&self.dw) {
            *w -= lr * g;
        }
        for (b, g) in self.b.iter_mut().zip(&self.db) {
            *b -= lr * g;
        }
        if let Some(mask) = &self.mask {
            mask.apply_inplace(&mut self.w);
        }
        self.zero_grad();
    }

    pub fn zero_grad(&mut self) {
        self.dw.iter_mut().for_each(|v| *v = 0.0);
        self.db.iter_mut().for_each(|v| *v = 0.0);
    }

    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    /// Surviving parameter count after masking (weights on the mask + biases).
    pub fn effective_param_count(&self) -> usize {
        match &self.mask {
            Some(m) => m.nnz() + self.b.len(),
            None => self.param_count(),
        }
    }
}

/// ReLU with cached activation sign for backward.
#[derive(Default)]
pub struct Relu {
    active: Vec<bool>,
}

impl Relu {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        self.active = x.iter().map(|&v| v > 0.0).collect();
        x.iter().map(|&v| v.max(0.0)).collect()
    }

    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        assert_eq!(dy.len(), self.active.len());
        dy.iter().zip(&self.active).map(|(&g, &a)| if a { g } else { 0.0 }).collect()
    }
}

/// Row-wise softmax (numerically stabilized).
pub fn softmax(x: &[f32], batch: usize, classes: usize) -> Vec<f32> {
    assert_eq!(x.len(), batch * classes);
    let mut out = vec![0.0f32; x.len()];
    for bi in 0..batch {
        let row = &x[bi * classes..(bi + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let orow = &mut out[bi * classes..(bi + 1) * classes];
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - m).exp();
            sum += *o;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    out
}

/// Mean cross-entropy loss over the batch + gradient w.r.t. logits
/// (softmax-xent fused backward: `p - onehot`).
pub fn softmax_xent(logits: &[f32], labels: &[u32], batch: usize, classes: usize) -> (f32, Vec<f32>) {
    let p = softmax(logits, batch, classes);
    let mut loss = 0.0f64;
    let mut dlogits = p.clone();
    for bi in 0..batch {
        let y = labels[bi] as usize;
        assert!(y < classes, "label out of range");
        let py = p[bi * classes + y].max(1e-12);
        loss -= (py as f64).ln();
        dlogits[bi * classes + y] -= 1.0;
    }
    let scale = 1.0 / batch as f32;
    dlogits.iter_mut().for_each(|v| *v *= scale);
    ((loss / batch as f64) as f32, dlogits)
}

/// Classification accuracy of logits vs labels.
pub fn accuracy(logits: &[f32], labels: &[u32], batch: usize, classes: usize) -> f64 {
    let mut correct = 0usize;
    for bi in 0..batch {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let mut best = 0usize;
        for c in 1..classes {
            if row[c] > row[best] {
                best = c;
            }
        }
        if best == labels[bi] as usize {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

/// Top-k accuracy (paper reports top-1 and top-5 on AlexNet).
pub fn topk_accuracy(logits: &[f32], labels: &[u32], batch: usize, classes: usize, k: usize) -> f64 {
    let mut correct = 0usize;
    for bi in 0..batch {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let y = labels[bi] as usize;
        let ylogit = row[y];
        // rank of the true class = #classes with strictly larger logit
        let rank = row.iter().filter(|&&v| v > ylogit).count();
        if rank < k {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

/// Inference-only FC layer variants competing in the §3.3 speedup study.
pub enum FcVariant {
    /// Dense `[out × in]` GEMM — the uncompressed baseline.
    Dense { w: Vec<f32>, out_dim: usize, in_dim: usize },
    /// CSR over the masked (irregular in storage order) weights.
    Sparse(Csr),
    /// Packed block-diagonal (MPD after eq. 2) — the paper's format.
    BlockDiag(BlockDiagMatrix),
}

impl FcVariant {
    /// `Y += X·Wᵀ` under each representation.
    pub fn matmul(&self, x: &[f32], y: &mut [f32], batch: usize) {
        match self {
            FcVariant::Dense { w, out_dim, in_dim } => {
                gemm_a_bt(x, w, y, batch, *in_dim, *out_dim);
            }
            FcVariant::Sparse(csr) => csr.spmm_xt(x, y, batch),
            FcVariant::BlockDiag(bd) => bd.matmul_xt(x, y, batch),
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            FcVariant::Dense { w, .. } => w.len() * 4,
            FcVariant::Sparse(csr) => csr.storage_bytes(),
            FcVariant::BlockDiag(bd) => bd.storage_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(seed)
    }

    #[test]
    fn linear_forward_shapes_and_bias() {
        let mut r = rng(1);
        let mut l = Linear::new(3, 4, &mut r);
        l.b = vec![1.0, 2.0, 3.0];
        l.w.iter_mut().for_each(|v| *v = 0.0);
        let y = l.forward(&[0.5; 8], 2);
        assert_eq!(y, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn linear_gradcheck() {
        // numerical gradient check on a tiny layer
        let mut r = rng(2);
        let (out, inp, batch) = (3, 4, 2);
        let mut l = Linear::new(out, inp, &mut r);
        let x: Vec<f32> = (0..batch * inp).map(|i| (i as f32 * 0.3).sin()).collect();
        let labels = vec![0u32, 2];

        let loss_of = |l: &mut Linear, x: &[f32]| {
            let y = l.forward(x, batch);
            softmax_xent(&y, &labels, batch, out).0
        };

        // analytic grads
        let y = l.forward(&x, batch);
        let (_, dy) = softmax_xent(&y, &labels, batch, out);
        l.zero_grad();
        let dx = l.backward(&dy);

        let eps = 1e-3f32;
        // check dW at a few positions
        for &idx in &[0usize, 5, 11] {
            let orig = l.w[idx];
            l.w[idx] = orig + eps;
            let lp = loss_of(&mut l, &x);
            l.w[idx] = orig - eps;
            let lm = loss_of(&mut l, &x);
            l.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            // recompute analytic after restoring
            let y = l.forward(&x, batch);
            let (_, dy2) = softmax_xent(&y, &labels, batch, out);
            l.zero_grad();
            l.backward(&dy2);
            assert!((l.dw[idx] - num).abs() < 1e-2, "dW[{idx}]: {} vs {}", l.dw[idx], num);
        }
        // check dX at one position
        let mut x2 = x.clone();
        let idx = 3;
        let orig = x2[idx];
        x2[idx] = orig + eps;
        let lp = loss_of(&mut l, &x2);
        x2[idx] = orig - eps;
        let lm = loss_of(&mut l, &x2);
        let num = (lp - lm) / (2.0 * eps);
        assert!((dx[idx] - num).abs() < 1e-2, "dX[{idx}]: {} vs {num}", dx[idx]);
    }

    #[test]
    fn masked_layer_keeps_weights_on_mask() {
        let mut r = rng(3);
        let mask = MpdMask::generate(6, 8, 2, &mut r);
        let dense_mask = mask.to_dense();
        let mut l = Linear::new(6, 8, &mut r).with_mask(mask);
        // after init, off-mask weights are zero
        for (i, &m) in dense_mask.iter().enumerate() {
            if m == 0.0 {
                assert_eq!(l.w[i], 0.0);
            }
        }
        // after a training step they stay zero
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let y = l.forward(&x, 2);
        let (_, dy) = softmax_xent(&y, &[1, 3], 2, 6);
        l.backward(&dy);
        l.sgd_step(0.1);
        for (i, &m) in dense_mask.iter().enumerate() {
            if m == 0.0 {
                assert_eq!(l.w[i], 0.0, "weight {i} leaked off-mask");
            }
        }
    }

    #[test]
    fn relu_forward_backward() {
        let mut relu = Relu::new();
        let y = relu.forward(&[-1.0, 0.0, 2.0]);
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        let dx = relu.backward(&[5.0, 5.0, 5.0]);
        assert_eq!(dx, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0], 2, 3);
        for bi in 0..2 {
            let s: f32 = p[bi * 3..(bi + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn xent_of_perfect_prediction_is_small() {
        let logits = vec![10.0, -10.0, -10.0];
        let (loss, _) = softmax_xent(&logits, &[0], 1, 3);
        assert!(loss < 1e-3);
        let (loss_bad, _) = softmax_xent(&logits, &[1], 1, 3);
        assert!(loss_bad > 5.0);
    }

    #[test]
    fn accuracy_and_topk() {
        // logits: sample0 best=2, sample1 best=0
        let logits = vec![0.1, 0.2, 0.9, 0.8, 0.1, 0.3];
        assert_eq!(accuracy(&logits, &[2, 0], 2, 3), 1.0);
        assert_eq!(accuracy(&logits, &[1, 0], 2, 3), 0.5);
        // top-2: sample0 label 1 is rank 2 (0.2 < 0.9, > 0.1) → within top-2
        assert_eq!(topk_accuracy(&logits, &[1, 2], 2, 3, 2), 1.0);
        assert_eq!(topk_accuracy(&logits, &[1, 2], 2, 3, 1), 0.0);
    }

    #[test]
    fn fc_variants_agree() {
        let mut r = rng(4);
        let mask = MpdMask::generate(20, 30, 5, &mut r);
        let w: Vec<f32> = (0..600).map(|_| r.next_f32() - 0.5).collect();
        let wm = mask.apply(&w);
        let dense = FcVariant::Dense { w: wm.clone(), out_dim: 20, in_dim: 30 };
        let sparse = FcVariant::Sparse(Csr::from_dense(&wm, 20, 30));
        let batch = 3;
        let x: Vec<f32> = (0..batch * 30).map(|_| r.next_f32()).collect();
        let mut y_dense = vec![0.0; batch * 20];
        dense.matmul(&x, &mut y_dense, batch);
        let mut y_sparse = vec![0.0; batch * 20];
        sparse.matmul(&x, &mut y_sparse, batch);
        for (a, b) in y_dense.iter().zip(&y_sparse) {
            assert!((a - b).abs() < 1e-4);
        }
        // storage ordering: blockdiag < csr < dense at 20% density
        let bd = FcVariant::BlockDiag(BlockDiagMatrix::from_masked_weights(&mask, &wm));
        assert!(bd.storage_bytes() < sparse.storage_bytes());
        assert!(sparse.storage_bytes() < dense.storage_bytes());
    }
}
