//! Native trainable conv net — the Deep MNIST / CIFAR-10 workhorse: a stack
//! of `Conv2d → ReLU → (MaxPool)` stages followed by a dense FC head, all
//! trained with plain SGD under optional in-training MPD masking (conv masks
//! apply to the `(out_c × in_c·k·k)` filter matrix, FC masks to the weight
//! matrix, both re-applied after every update — Algorithm 1).
//!
//! The forward value stream is deliberately identical to the compressed
//! inference path (`compress::conv_model::PackedConvNet`): convs accumulate
//! taps in filter-matrix column order with the bias added last, ReLU follows
//! each conv, pooling uses first-maximum tie-breaking, activations flatten in
//! NCHW order into the head. For unmasked models the two paths are
//! bit-identical; under masks they agree to float tolerance (the packed
//! kernel sums each block's taps in permuted order).

use crate::mask::mask::MpdMask;
use crate::mask::prng::Xoshiro256pp;
use crate::nn::checkpoint::NamedTensor;
use crate::nn::conv::{Conv2d, MaxPool2d};
use crate::nn::layer::{accuracy, softmax_xent, Linear, Relu};

/// One conv stage of a [`ConvNetSpec`]: a square-kernel convolution plus an
/// optional max-pool (`pool_k == 0` disables pooling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvStageSpec {
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub pool_k: usize,
    pub pool_stride: usize,
}

impl ConvStageSpec {
    /// `k×k` stride-1 conv with `pad = k/2` followed by a `p×p` stride-`p`
    /// pool. Output-preserving ("same") for odd `k`; even kernels grow the
    /// output by one — construct the struct directly for other geometries.
    pub fn same(out_c: usize, k: usize, pool: usize) -> Self {
        Self { out_c, k, stride: 1, pad: k / 2, pool_k: pool, pool_stride: pool }
    }

    pub fn has_pool(&self) -> bool {
        self.pool_k > 0
    }
}

/// Architecture of a conv net: NCHW input shape, conv stages, FC head dims
/// (`fc_dims[0]` must equal the flattened conv output; last entry is the
/// class count).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvNetSpec {
    /// `(channels, height, width)`.
    pub input: (usize, usize, usize),
    pub convs: Vec<ConvStageSpec>,
    pub fc_dims: Vec<usize>,
}

impl ConvNetSpec {
    /// Per-stage `(in_c, h, w)` at the *input* of each conv, plus the final
    /// `(c, h, w)` after the last stage.
    pub fn stage_shapes(&self) -> Vec<(usize, usize, usize)> {
        let (mut c, mut h, mut w) = self.input;
        let mut shapes = Vec::with_capacity(self.convs.len() + 1);
        for s in &self.convs {
            shapes.push((c, h, w));
            h = (h + 2 * s.pad - s.k) / s.stride + 1;
            w = (w + 2 * s.pad - s.k) / s.stride + 1;
            c = s.out_c;
            if s.has_pool() {
                h = (h - s.pool_k) / s.pool_stride + 1;
                w = (w - s.pool_k) / s.pool_stride + 1;
            }
        }
        shapes.push((c, h, w));
        shapes
    }

    /// Flattened feature count entering the FC head.
    pub fn conv_out_dim(&self) -> usize {
        let &(c, h, w) = self.stage_shapes().last().unwrap();
        c * h * w
    }

    pub fn in_dim(&self) -> usize {
        let (c, h, w) = self.input;
        c * h * w
    }

    pub fn validate(&self) -> Result<(), String> {
        let (c, h, w) = self.input;
        if c == 0 || h == 0 || w == 0 {
            return Err("convnet input has a zero dimension".into());
        }
        if self.fc_dims.len() < 2 {
            return Err("convnet head needs at least [in, out] dims".into());
        }
        let (mut c, mut h, mut w) = self.input;
        for (i, s) in self.convs.iter().enumerate() {
            if s.out_c == 0 || s.k == 0 || s.stride == 0 {
                return Err(format!("conv stage {i}: zero dimension"));
            }
            if h + 2 * s.pad < s.k || w + 2 * s.pad < s.k {
                return Err(format!("conv stage {i}: kernel {} does not fit {h}×{w} (pad {})", s.k, s.pad));
            }
            h = (h + 2 * s.pad - s.k) / s.stride + 1;
            w = (w + 2 * s.pad - s.k) / s.stride + 1;
            c = s.out_c;
            if s.has_pool() {
                if s.pool_stride == 0 {
                    return Err(format!("conv stage {i}: zero pool stride"));
                }
                if h < s.pool_k || w < s.pool_k {
                    return Err(format!("conv stage {i}: pool {} does not fit {h}×{w}", s.pool_k));
                }
                h = (h - s.pool_k) / s.pool_stride + 1;
                w = (w - s.pool_k) / s.pool_stride + 1;
            }
        }
        if self.fc_dims[0] != c * h * w {
            return Err(format!(
                "head input dim {} != flattened conv output {} ({c}×{h}×{w})",
                self.fc_dims[0],
                c * h * w
            ));
        }
        Ok(())
    }
}

/// A trainable conv net: conv stages + FC head, NCHW activations flattened
/// row-major between the two.
pub struct ConvNet {
    pub spec: ConvNetSpec,
    pub convs: Vec<Conv2d>,
    pools: Vec<Option<MaxPool2d>>,
    conv_relus: Vec<Relu>,
    pub fcs: Vec<Linear>,
    fc_relus: Vec<Relu>,
    /// `(in_c, h, w)` at each conv's input (cached from the spec).
    shapes: Vec<(usize, usize, usize)>,
}

impl ConvNet {
    pub fn new(spec: ConvNetSpec, rng: &mut Xoshiro256pp) -> Self {
        spec.validate().expect("valid convnet spec");
        let shapes = spec.stage_shapes();
        let convs: Vec<Conv2d> = spec
            .convs
            .iter()
            .zip(&shapes)
            .map(|(s, &(in_c, _, _))| Conv2d::new(s.out_c, in_c, s.k, s.stride, s.pad, rng))
            .collect();
        let pools = spec
            .convs
            .iter()
            .map(|s| s.has_pool().then(|| MaxPool2d::new(s.pool_k, s.pool_stride)))
            .collect();
        let conv_relus = (0..spec.convs.len()).map(|_| Relu::new()).collect();
        let fcs = spec.fc_dims.windows(2).map(|d| Linear::new(d[1], d[0], rng)).collect::<Vec<_>>();
        let fc_relus = (0..spec.fc_dims.len().saturating_sub(2)).map(|_| Relu::new()).collect();
        Self { spec, convs, pools, conv_relus, fcs, fc_relus, shapes }
    }

    /// Attach MPD masks: `conv_masks[i]` over conv `i`'s filter matrix,
    /// `fc_masks[j]` over FC layer `j` (None = dense). Masks are applied
    /// immediately and re-applied after every SGD step.
    pub fn with_masks(mut self, conv_masks: Vec<Option<MpdMask>>, fc_masks: Vec<Option<MpdMask>>) -> Self {
        assert_eq!(conv_masks.len(), self.convs.len());
        assert_eq!(fc_masks.len(), self.fcs.len());
        let convs = std::mem::take(&mut self.convs);
        self.convs = convs
            .into_iter()
            .zip(conv_masks)
            .map(|(c, m)| match m {
                Some(mask) => c.with_mask(mask),
                None => c,
            })
            .collect();
        let fcs = std::mem::take(&mut self.fcs);
        self.fcs = fcs
            .into_iter()
            .zip(fc_masks)
            .map(|(l, m)| match m {
                Some(mask) => l.with_mask(mask),
                None => l,
            })
            .collect();
        self
    }

    pub fn in_dim(&self) -> usize {
        self.spec.in_dim()
    }

    pub fn out_dim(&self) -> usize {
        *self.spec.fc_dims.last().unwrap()
    }

    /// Forward a batch of flattened NCHW inputs `[batch × in_dim]` → logits.
    pub fn forward(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_dim());
        let mut act = x.to_vec();
        for i in 0..self.convs.len() {
            let (_, h, w) = self.shapes[i];
            act = self.convs[i].forward(&act, batch, h, w);
            act = self.conv_relus[i].forward(&act);
            if let Some(p) = &mut self.pools[i] {
                let (oh, ow) = self.convs[i].out_hw(h, w);
                act = p.forward(&act, batch, self.convs[i].out_c, oh, ow);
            }
        }
        let n = self.fcs.len();
        act = self.fcs[0].forward(&act, batch);
        for j in 1..n {
            act = self.fc_relus[j - 1].forward(&act);
            act = self.fcs[j].forward(&act, batch);
        }
        act
    }

    /// One SGD step on a batch; returns the loss.
    pub fn train_step(&mut self, x: &[f32], labels: &[u32], batch: usize, lr: f32) -> f32 {
        let classes = self.out_dim();
        let logits = self.forward(x, batch);
        let (loss, mut grad) = softmax_xent(&logits, labels, batch, classes);
        let n = self.fcs.len();
        for j in (0..n).rev() {
            grad = self.fcs[j].backward(&grad);
            if j > 0 {
                grad = self.fc_relus[j - 1].backward(&grad);
            }
        }
        for i in (0..self.convs.len()).rev() {
            if let Some(p) = &self.pools[i] {
                grad = p.backward(&grad);
            }
            grad = self.conv_relus[i].backward(&grad);
            grad = self.convs[i].backward(&grad);
        }
        for c in &mut self.convs {
            c.sgd_step(lr);
        }
        for l in &mut self.fcs {
            l.sgd_step(lr);
        }
        loss
    }

    /// Accuracy over a batch.
    pub fn evaluate(&mut self, x: &[f32], labels: &[u32], batch: usize) -> f64 {
        let classes = self.out_dim();
        let logits = self.forward(x, batch);
        accuracy(&logits, labels, batch, classes)
    }

    pub fn param_count(&self) -> usize {
        self.convs.iter().map(|c| c.param_count()).sum::<usize>()
            + self.fcs.iter().map(|l| l.param_count()).sum::<usize>()
    }

    /// Surviving parameters after masking (Table-1 accounting for mixed
    /// conv+dense models).
    pub fn effective_param_count(&self) -> usize {
        self.convs.iter().map(|c| c.effective_param_count()).sum::<usize>()
            + self.fcs.iter().map(|l| l.effective_param_count()).sum::<usize>()
    }

    /// Named checkpoint tensors: `conv{i}.w [out_c, in_c, kh, kw]`,
    /// `conv{i}.b`, `fc{j}.w [out, in]`, `fc{j}.b` — plain f32 tensors, so a
    /// conv model round-trips through checkpoint format v1 unchanged.
    pub fn named_tensors(&self) -> Vec<NamedTensor> {
        let mut out = Vec::new();
        for (i, c) in self.convs.iter().enumerate() {
            out.push(NamedTensor::f32(
                format!("conv{i}.w"),
                vec![c.out_c, c.in_c, c.kh, c.kw],
                c.w.clone(),
            ));
            out.push(NamedTensor::f32(format!("conv{i}.b"), vec![c.out_c], c.b.clone()));
        }
        for (j, l) in self.fcs.iter().enumerate() {
            out.push(NamedTensor::f32(format!("fc{j}.w"), vec![l.out_dim, l.in_dim], l.w.clone()));
            out.push(NamedTensor::f32(format!("fc{j}.b"), vec![l.out_dim], l.b.clone()));
        }
        out
    }

    /// Load parameters saved by [`Self::named_tensors`] (shape-checked).
    /// Attached masks are re-applied after loading, so a checkpoint trained
    /// under different masks cannot leak off-block weights.
    pub fn load_tensors(&mut self, tensors: &[NamedTensor]) -> Result<(), String> {
        let find = |name: &str| -> Result<&NamedTensor, String> {
            tensors.iter().find(|t| t.name == name).ok_or_else(|| format!("missing tensor {name}"))
        };
        for (i, c) in self.convs.iter_mut().enumerate() {
            let w = find(&format!("conv{i}.w"))?;
            if w.shape != vec![c.out_c, c.in_c, c.kh, c.kw] {
                return Err(format!("conv{i}.w: shape {:?} mismatch", w.shape));
            }
            c.w = w.as_f32().ok_or_else(|| format!("conv{i}.w: not f32"))?.to_vec();
            if let Some(m) = &c.mask {
                m.apply_inplace(&mut c.w);
            }
            let b = find(&format!("conv{i}.b"))?;
            if b.shape != vec![c.out_c] {
                return Err(format!("conv{i}.b: shape {:?} mismatch", b.shape));
            }
            c.b = b.as_f32().ok_or_else(|| format!("conv{i}.b: not f32"))?.to_vec();
        }
        for (j, l) in self.fcs.iter_mut().enumerate() {
            let w = find(&format!("fc{j}.w"))?;
            if w.shape != vec![l.out_dim, l.in_dim] {
                return Err(format!("fc{j}.w: shape {:?} mismatch", w.shape));
            }
            l.w = w.as_f32().ok_or_else(|| format!("fc{j}.w: not f32"))?.to_vec();
            if let Some(m) = &l.mask {
                m.apply_inplace(&mut l.w);
            }
            let b = find(&format!("fc{j}.b"))?;
            if b.shape != vec![l.out_dim] {
                return Err(format!("fc{j}.b: shape {:?} mismatch", b.shape));
            }
            l.b = b.as_f32().ok_or_else(|| format!("fc{j}.b: not f32"))?.to_vec();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ConvNetSpec {
        ConvNetSpec {
            input: (1, 8, 8),
            convs: vec![ConvStageSpec::same(4, 3, 2), ConvStageSpec::same(6, 3, 2)],
            fc_dims: vec![6 * 2 * 2, 16, 3],
        }
    }

    #[test]
    fn spec_shapes_and_validation() {
        let spec = tiny_spec();
        spec.validate().unwrap();
        assert_eq!(spec.stage_shapes(), vec![(1, 8, 8), (4, 4, 4), (6, 2, 2)]);
        assert_eq!(spec.conv_out_dim(), 24);
        let mut bad = tiny_spec();
        bad.fc_dims[0] = 25;
        assert!(bad.validate().is_err());
        let mut bad = tiny_spec();
        bad.convs[0].k = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn deep_mnist_paper_spec_shapes() {
        // TF-tutorial Deep MNIST: conv 5×5×32 pool2 → conv 5×5×64 pool2 →
        // fc 3136→1024→10 (paper Table 1's 3.22M FC params).
        let spec = ConvNetSpec {
            input: (1, 28, 28),
            convs: vec![ConvStageSpec::same(32, 5, 2), ConvStageSpec::same(64, 5, 2)],
            fc_dims: vec![3136, 1024, 10],
        };
        spec.validate().unwrap();
        assert_eq!(spec.conv_out_dim(), 64 * 7 * 7);
    }

    #[test]
    fn learns_tiny_synthetic_task() {
        // 3-class blobs rendered as 8×8 images with class-keyed quadrants.
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let spec = tiny_spec();
        let mut net = ConvNet::new(spec.clone(), &mut rng);
        let n = 60;
        let mut x = Vec::with_capacity(n * 64);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = (i % 3) as u32;
            for p in 0..64 {
                let (py, px) = (p / 8, p % 8);
                let on = match label {
                    0 => py < 4,
                    1 => px < 4,
                    _ => (py + px) % 2 == 0,
                };
                x.push(if on { 1.0 } else { -1.0 } + (rng.next_f32() - 0.5) * 0.3);
            }
            y.push(label);
        }
        let first = net.train_step(&x, &y, n, 0.05);
        let mut last = first;
        for _ in 0..40 {
            last = net.train_step(&x, &y, n, 0.05);
        }
        assert!(last < first * 0.6, "loss {first} → {last} did not drop");
        assert!(net.evaluate(&x, &y, n) > 0.8);
    }

    #[test]
    fn masked_training_confines_weights() {
        use crate::mask::blockdiag::off_block_mass;
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let spec = tiny_spec();
        // mask conv1's 6×(4·9)=6×36 filter matrix and fc0's 16×24 matrix
        let conv_mask = MpdMask::generate(6, 36, 3, &mut rng);
        let fc_mask = MpdMask::generate(16, 24, 4, &mut rng);
        let (cm, fm) = (conv_mask.clone(), fc_mask.clone());
        let mut net = ConvNet::new(spec, &mut rng)
            .with_masks(vec![None, Some(conv_mask)], vec![Some(fc_mask), None]);
        let x: Vec<f32> = (0..5 * 64).map(|i| (i as f32 * 0.17).sin()).collect();
        let y = vec![0u32, 1, 2, 0, 1];
        for _ in 0..5 {
            net.train_step(&x, &y, 5, 0.05);
        }
        assert_eq!(off_block_mass(&cm.unpermute(&net.convs[1].w), &cm.layout), 0.0);
        assert_eq!(off_block_mass(&fm.unpermute(&net.fcs[0].w), &fm.layout), 0.0);
        assert!(net.effective_param_count() < net.param_count());
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let spec = tiny_spec();
        let a = ConvNet::new(spec.clone(), &mut rng);
        let mut b = ConvNet::new(spec, &mut rng);
        let tensors = a.named_tensors();
        assert_eq!(tensors.len(), 2 * 2 + 2 * 2);
        b.load_tensors(&tensors).unwrap();
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.w, cb.w);
            assert_eq!(ca.b, cb.b);
        }
        for (la, lb) in a.fcs.iter().zip(&b.fcs) {
            assert_eq!(la.w, lb.w);
            assert_eq!(la.b, lb.b);
        }
        // bad shape rejected
        let mut bad = a.named_tensors();
        bad[0] = NamedTensor::f32("conv0.w", vec![1, 1, 1, 1], vec![0.0]);
        assert!(b.load_tensors(&bad).is_err());
    }
}
