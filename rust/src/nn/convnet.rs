//! Native trainable conv net — the Deep MNIST / CIFAR-10 workhorse: a stack
//! of `Conv2d → ReLU → (MaxPool)` stages followed by a dense FC head, all
//! trained with plain SGD under optional in-training MPD masking (conv masks
//! apply to the `(out_c × in_c·k·k)` filter matrix, FC masks to the weight
//! matrix, both re-applied after every update — Algorithm 1).
//!
//! The forward value stream is deliberately identical to the compressed
//! inference path (`compress::conv_model::PackedConvNet`): convs accumulate
//! taps in filter-matrix column order with the bias added last, ReLU follows
//! each conv, pooling uses first-maximum tie-breaking, activations flatten in
//! NCHW order into the head. For unmasked models the two paths are
//! bit-identical; under masks they agree to float tolerance (the packed
//! kernel sums each block's taps in permuted order).

use crate::mask::mask::MpdMask;
use crate::mask::prng::Xoshiro256pp;
use crate::nn::checkpoint::NamedTensor;
use crate::nn::conv::{AvgPool2d, Conv2d, MaxPool2d};
use crate::nn::layer::{accuracy, softmax_xent, Linear, Relu};

/// Which pooling (if any) follows a conv stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    None,
    Max,
    Avg,
    /// Global average pooling: one value per channel (`k` is derived from
    /// the stage's output spatial size, which must be square).
    GlobalAvg,
}

/// One conv stage of a [`ConvNetSpec`]: a square-kernel (optionally grouped)
/// convolution, an optional residual save/add, an optional ReLU, and an
/// optional pool.
///
/// Stage semantics (the order the compressed lowering reproduces op-for-op):
///
/// 1. if `save_skip`: snapshot the stage *input* as the residual branch
/// 2. convolve (`groups`-grouped, strided, padded)
/// 3. if `add_skip`: add the pending snapshot elementwise
/// 4. if `relu`: ReLU
/// 5. pool per `pool_kind`
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvStageSpec {
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// AlexNet-style channel groups (must divide both in/out channels).
    pub groups: usize,
    /// ReLU after the conv (and after the residual add, when present).
    pub relu: bool,
    /// Snapshot this stage's input as the pending residual branch.
    pub save_skip: bool,
    /// Add the pending snapshot to this stage's conv output.
    pub add_skip: bool,
    pub pool_kind: PoolKind,
    pub pool_k: usize,
    pub pool_stride: usize,
}

impl ConvStageSpec {
    /// `k×k` stride-1 conv with `pad = k/2` followed by a `p×p` stride-`p`
    /// max-pool (`pool == 0` disables pooling). Output-preserving ("same")
    /// for odd `k`; even kernels grow the output by one — use the builder
    /// methods / struct literal for other geometries.
    pub fn same(out_c: usize, k: usize, pool: usize) -> Self {
        Self {
            out_c,
            k,
            stride: 1,
            pad: k / 2,
            groups: 1,
            relu: true,
            save_skip: false,
            add_skip: false,
            pool_kind: if pool > 0 { PoolKind::Max } else { PoolKind::None },
            pool_k: pool,
            pool_stride: pool,
        }
    }

    /// A bare conv stage (stride/pad explicit, no pool).
    pub fn plain(out_c: usize, k: usize, stride: usize, pad: usize) -> Self {
        Self {
            out_c,
            k,
            stride,
            pad,
            groups: 1,
            relu: true,
            save_skip: false,
            add_skip: false,
            pool_kind: PoolKind::None,
            pool_k: 0,
            pool_stride: 0,
        }
    }

    pub fn grouped(mut self, groups: usize) -> Self {
        self.groups = groups;
        self
    }

    pub fn no_relu(mut self) -> Self {
        self.relu = false;
        self
    }

    pub fn saving_skip(mut self) -> Self {
        self.save_skip = true;
        self
    }

    pub fn adding_skip(mut self) -> Self {
        self.add_skip = true;
        self
    }

    pub fn max_pool(mut self, k: usize, stride: usize) -> Self {
        self.pool_kind = PoolKind::Max;
        self.pool_k = k;
        self.pool_stride = stride;
        self
    }

    pub fn avg_pool(mut self, k: usize, stride: usize) -> Self {
        self.pool_kind = PoolKind::Avg;
        self.pool_k = k;
        self.pool_stride = stride;
        self
    }

    /// Global average pooling: `k` is derived from the stage output size.
    pub fn global_avg_pool(mut self) -> Self {
        self.pool_kind = PoolKind::GlobalAvg;
        self.pool_k = 0;
        self.pool_stride = 1;
        self
    }

    pub fn has_pool(&self) -> bool {
        self.pool_kind != PoolKind::None
    }

    /// Conv-output spatial dims before pooling.
    pub fn conv_out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h + 2 * self.pad - self.k) / self.stride + 1, (w + 2 * self.pad - self.k) / self.stride + 1)
    }

    /// Spatial dims after the stage's pool (identity for `PoolKind::None`).
    pub fn pooled_hw(&self, oh: usize, ow: usize) -> (usize, usize) {
        match self.pool_kind {
            PoolKind::None => (oh, ow),
            PoolKind::Max | PoolKind::Avg => (
                (oh - self.pool_k) / self.pool_stride + 1,
                (ow - self.pool_k) / self.pool_stride + 1,
            ),
            PoolKind::GlobalAvg => (1, 1),
        }
    }
}

/// Architecture of a conv net: NCHW input shape, conv stages, FC head dims
/// (`fc_dims[0]` must equal the flattened conv output; last entry is the
/// class count).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvNetSpec {
    /// `(channels, height, width)`.
    pub input: (usize, usize, usize),
    pub convs: Vec<ConvStageSpec>,
    pub fc_dims: Vec<usize>,
}

impl ConvNetSpec {
    /// Per-stage `(in_c, h, w)` at the *input* of each conv, plus the final
    /// `(c, h, w)` after the last stage.
    pub fn stage_shapes(&self) -> Vec<(usize, usize, usize)> {
        let (mut c, mut h, mut w) = self.input;
        let mut shapes = Vec::with_capacity(self.convs.len() + 1);
        for s in &self.convs {
            shapes.push((c, h, w));
            let (oh, ow) = s.conv_out_hw(h, w);
            let (ph, pw) = s.pooled_hw(oh, ow);
            c = s.out_c;
            h = ph;
            w = pw;
        }
        shapes.push((c, h, w));
        shapes
    }

    /// Flattened feature count entering the FC head.
    pub fn conv_out_dim(&self) -> usize {
        let &(c, h, w) = self.stage_shapes().last().unwrap();
        c * h * w
    }

    pub fn in_dim(&self) -> usize {
        let (c, h, w) = self.input;
        c * h * w
    }

    pub fn validate(&self) -> Result<(), String> {
        let (c, h, w) = self.input;
        if c == 0 || h == 0 || w == 0 {
            return Err("convnet input has a zero dimension".into());
        }
        if self.fc_dims.len() < 2 {
            return Err("convnet head needs at least [in, out] dims".into());
        }
        let (mut c, mut h, mut w) = self.input;
        // Pending residual snapshot shape (set by save_skip, cleared by
        // add_skip) — the add must see the exact saved (c, h, w).
        let mut pending: Option<(usize, usize, usize)> = None;
        for (i, s) in self.convs.iter().enumerate() {
            if s.out_c == 0 || s.k == 0 || s.stride == 0 {
                return Err(format!("conv stage {i}: zero dimension"));
            }
            if s.groups == 0 || c % s.groups != 0 || s.out_c % s.groups != 0 {
                return Err(format!(
                    "conv stage {i}: groups {} must divide in channels {c} and out channels {}",
                    s.groups, s.out_c
                ));
            }
            if h + 2 * s.pad < s.k || w + 2 * s.pad < s.k {
                return Err(format!("conv stage {i}: kernel {} does not fit {h}×{w} (pad {})", s.k, s.pad));
            }
            if s.save_skip {
                if pending.is_some() {
                    return Err(format!("conv stage {i}: save_skip while a skip is already pending"));
                }
                pending = Some((c, h, w));
            }
            let (oh, ow) = s.conv_out_hw(h, w);
            if s.add_skip {
                match pending.take() {
                    None => return Err(format!("conv stage {i}: add_skip with no pending skip")),
                    Some(saved) if saved != (s.out_c, oh, ow) => {
                        return Err(format!(
                            "conv stage {i}: residual shapes differ: saved {:?} vs conv output {:?}",
                            saved,
                            (s.out_c, oh, ow)
                        ))
                    }
                    Some(_) => {}
                }
            }
            c = s.out_c;
            h = oh;
            w = ow;
            match s.pool_kind {
                PoolKind::None => {}
                PoolKind::Max | PoolKind::Avg => {
                    if s.pool_k == 0 || s.pool_stride == 0 {
                        return Err(format!("conv stage {i}: zero pool size/stride"));
                    }
                    if h < s.pool_k || w < s.pool_k {
                        return Err(format!("conv stage {i}: pool {} does not fit {h}×{w}", s.pool_k));
                    }
                }
                PoolKind::GlobalAvg => {
                    if h != w {
                        return Err(format!("conv stage {i}: global avg pool needs square input, got {h}×{w}"));
                    }
                }
            }
            let (ph, pw) = s.pooled_hw(h, w);
            h = ph;
            w = pw;
        }
        if pending.is_some() {
            return Err("convnet: dangling save_skip (no stage adds it back)".into());
        }
        if self.fc_dims[0] != c * h * w {
            return Err(format!(
                "head input dim {} != flattened conv output {} ({c}×{h}×{w})",
                self.fc_dims[0],
                c * h * w
            ));
        }
        Ok(())
    }
}

/// The pool layer a stage instantiated from its [`PoolKind`].
enum PoolLayer {
    Max(MaxPool2d),
    Avg(AvgPool2d),
}

/// A trainable conv net: conv stages + FC head, NCHW activations flattened
/// row-major between the two.
pub struct ConvNet {
    pub spec: ConvNetSpec,
    pub convs: Vec<Conv2d>,
    pools: Vec<Option<PoolLayer>>,
    conv_relus: Vec<Relu>,
    pub fcs: Vec<Linear>,
    fc_relus: Vec<Relu>,
    /// `(in_c, h, w)` at each conv's input (cached from the spec).
    shapes: Vec<(usize, usize, usize)>,
}

impl ConvNet {
    pub fn new(spec: ConvNetSpec, rng: &mut Xoshiro256pp) -> Self {
        spec.validate().expect("valid convnet spec");
        let shapes = spec.stage_shapes();
        let convs: Vec<Conv2d> = spec
            .convs
            .iter()
            .zip(&shapes)
            .map(|(s, &(in_c, _, _))| {
                Conv2d::new_grouped(s.out_c, in_c, s.k, s.stride, s.pad, s.groups, rng)
            })
            .collect();
        let pools = spec
            .convs
            .iter()
            .zip(&shapes)
            .map(|(s, &(_, h, w))| {
                let (oh, _ow) = s.conv_out_hw(h, w);
                match s.pool_kind {
                    PoolKind::None => None,
                    PoolKind::Max => Some(PoolLayer::Max(MaxPool2d::new(s.pool_k, s.pool_stride))),
                    PoolKind::Avg => Some(PoolLayer::Avg(AvgPool2d::new(s.pool_k, s.pool_stride))),
                    // Global pooling is a full-window average over the
                    // stage's (square) conv output.
                    PoolKind::GlobalAvg => Some(PoolLayer::Avg(AvgPool2d::new(oh, 1))),
                }
            })
            .collect();
        let conv_relus = (0..spec.convs.len()).map(|_| Relu::new()).collect();
        let fcs = spec.fc_dims.windows(2).map(|d| Linear::new(d[1], d[0], rng)).collect::<Vec<_>>();
        let fc_relus = (0..spec.fc_dims.len().saturating_sub(2)).map(|_| Relu::new()).collect();
        Self { spec, convs, pools, conv_relus, fcs, fc_relus, shapes }
    }

    /// Attach MPD masks: `conv_masks[i]` over conv `i`'s filter matrix,
    /// `fc_masks[j]` over FC layer `j` (None = dense). Masks are applied
    /// immediately and re-applied after every SGD step.
    pub fn with_masks(mut self, conv_masks: Vec<Option<MpdMask>>, fc_masks: Vec<Option<MpdMask>>) -> Self {
        assert_eq!(conv_masks.len(), self.convs.len());
        assert_eq!(fc_masks.len(), self.fcs.len());
        let convs = std::mem::take(&mut self.convs);
        self.convs = convs
            .into_iter()
            .zip(conv_masks)
            .map(|(c, m)| match m {
                Some(mask) => c.with_mask(mask),
                None => c,
            })
            .collect();
        let fcs = std::mem::take(&mut self.fcs);
        self.fcs = fcs
            .into_iter()
            .zip(fc_masks)
            .map(|(l, m)| match m {
                Some(mask) => l.with_mask(mask),
                None => l,
            })
            .collect();
        self
    }

    pub fn in_dim(&self) -> usize {
        self.spec.in_dim()
    }

    pub fn out_dim(&self) -> usize {
        *self.spec.fc_dims.last().unwrap()
    }

    /// Forward a batch of flattened NCHW inputs `[batch × in_dim]` → logits.
    /// Stage order — snapshot, conv, residual add, ReLU, pool — matches the
    /// compressed lowering op-for-op (see `compress::conv_model`).
    pub fn forward(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_dim());
        let mut act = x.to_vec();
        let mut skip: Option<Vec<f32>> = None;
        for i in 0..self.convs.len() {
            let s = self.spec.convs[i];
            let (_, h, w) = self.shapes[i];
            if s.save_skip {
                skip = Some(act.clone());
            }
            act = self.convs[i].forward(&act, batch, h, w);
            if s.add_skip {
                let snap = skip.take().expect("validated: pending skip");
                for (a, &b) in act.iter_mut().zip(&snap) {
                    *a += b;
                }
            }
            if s.relu {
                act = self.conv_relus[i].forward(&act);
            }
            if let Some(p) = &mut self.pools[i] {
                let (oh, ow) = self.convs[i].out_hw(h, w);
                act = match p {
                    PoolLayer::Max(mp) => mp.forward(&act, batch, self.convs[i].out_c, oh, ow),
                    PoolLayer::Avg(ap) => ap.forward(&act, batch, self.convs[i].out_c, oh, ow),
                };
            }
        }
        let n = self.fcs.len();
        act = self.fcs[0].forward(&act, batch);
        for j in 1..n {
            act = self.fc_relus[j - 1].forward(&act);
            act = self.fcs[j].forward(&act, batch);
        }
        act
    }

    /// One SGD step on a batch; returns the loss.
    pub fn train_step(&mut self, x: &[f32], labels: &[u32], batch: usize, lr: f32) -> f32 {
        let classes = self.out_dim();
        let logits = self.forward(x, batch);
        let (loss, mut grad) = softmax_xent(&logits, labels, batch, classes);
        let n = self.fcs.len();
        for j in (0..n).rev() {
            grad = self.fcs[j].backward(&grad);
            if j > 0 {
                grad = self.fc_relus[j - 1].backward(&grad);
            }
        }
        // Reverse walk: pool → ReLU → (branch split at add) → conv → (branch
        // merge at save). The add is linear, so its gradient copies to both
        // the conv branch and the snapshot branch; the snapshot was the
        // saving stage's *input*, so its gradient joins after that stage's
        // conv backward.
        let mut skip_grad: Option<Vec<f32>> = None;
        for i in (0..self.convs.len()).rev() {
            let s = self.spec.convs[i];
            if let Some(p) = &self.pools[i] {
                grad = match p {
                    PoolLayer::Max(mp) => mp.backward(&grad),
                    PoolLayer::Avg(ap) => ap.backward(&grad),
                };
            }
            if s.relu {
                grad = self.conv_relus[i].backward(&grad);
            }
            if s.add_skip {
                skip_grad = Some(grad.clone());
            }
            grad = self.convs[i].backward(&grad);
            if s.save_skip {
                let sg = skip_grad.take().expect("validated: pending skip grad");
                for (g, &b) in grad.iter_mut().zip(&sg) {
                    *g += b;
                }
            }
        }
        for c in &mut self.convs {
            c.sgd_step(lr);
        }
        for l in &mut self.fcs {
            l.sgd_step(lr);
        }
        loss
    }

    /// Accuracy over a batch.
    pub fn evaluate(&mut self, x: &[f32], labels: &[u32], batch: usize) -> f64 {
        let classes = self.out_dim();
        let logits = self.forward(x, batch);
        accuracy(&logits, labels, batch, classes)
    }

    pub fn param_count(&self) -> usize {
        self.convs.iter().map(|c| c.param_count()).sum::<usize>()
            + self.fcs.iter().map(|l| l.param_count()).sum::<usize>()
    }

    /// Surviving parameters after masking (Table-1 accounting for mixed
    /// conv+dense models).
    pub fn effective_param_count(&self) -> usize {
        self.convs.iter().map(|c| c.effective_param_count()).sum::<usize>()
            + self.fcs.iter().map(|l| l.effective_param_count()).sum::<usize>()
    }

    /// Named checkpoint tensors: `conv{i}.w [out_c, in_c, kh, kw]`,
    /// `conv{i}.b`, `fc{j}.w [out, in]`, `fc{j}.b` — plain f32 tensors, so a
    /// conv model round-trips through checkpoint format v1 unchanged.
    pub fn named_tensors(&self) -> Vec<NamedTensor> {
        let mut out = Vec::new();
        for (i, c) in self.convs.iter().enumerate() {
            out.push(NamedTensor::f32(
                format!("conv{i}.w"),
                vec![c.out_c, c.in_c, c.kh, c.kw],
                c.w.clone(),
            ));
            out.push(NamedTensor::f32(format!("conv{i}.b"), vec![c.out_c], c.b.clone()));
        }
        for (j, l) in self.fcs.iter().enumerate() {
            out.push(NamedTensor::f32(format!("fc{j}.w"), vec![l.out_dim, l.in_dim], l.w.clone()));
            out.push(NamedTensor::f32(format!("fc{j}.b"), vec![l.out_dim], l.b.clone()));
        }
        out
    }

    /// Load parameters saved by [`Self::named_tensors`] (shape-checked).
    /// Attached masks are re-applied after loading, so a checkpoint trained
    /// under different masks cannot leak off-block weights.
    pub fn load_tensors(&mut self, tensors: &[NamedTensor]) -> Result<(), String> {
        let find = |name: &str| -> Result<&NamedTensor, String> {
            tensors.iter().find(|t| t.name == name).ok_or_else(|| format!("missing tensor {name}"))
        };
        for (i, c) in self.convs.iter_mut().enumerate() {
            let w = find(&format!("conv{i}.w"))?;
            if w.shape != vec![c.out_c, c.in_c, c.kh, c.kw] {
                return Err(format!("conv{i}.w: shape {:?} mismatch", w.shape));
            }
            c.w = w.as_f32().ok_or_else(|| format!("conv{i}.w: not f32"))?.to_vec();
            if let Some(m) = &c.mask {
                m.apply_inplace(&mut c.w);
            }
            let b = find(&format!("conv{i}.b"))?;
            if b.shape != vec![c.out_c] {
                return Err(format!("conv{i}.b: shape {:?} mismatch", b.shape));
            }
            c.b = b.as_f32().ok_or_else(|| format!("conv{i}.b: not f32"))?.to_vec();
        }
        for (j, l) in self.fcs.iter_mut().enumerate() {
            let w = find(&format!("fc{j}.w"))?;
            if w.shape != vec![l.out_dim, l.in_dim] {
                return Err(format!("fc{j}.w: shape {:?} mismatch", w.shape));
            }
            l.w = w.as_f32().ok_or_else(|| format!("fc{j}.w: not f32"))?.to_vec();
            if let Some(m) = &l.mask {
                m.apply_inplace(&mut l.w);
            }
            let b = find(&format!("fc{j}.b"))?;
            if b.shape != vec![l.out_dim] {
                return Err(format!("fc{j}.b: shape {:?} mismatch", b.shape));
            }
            l.b = b.as_f32().ok_or_else(|| format!("fc{j}.b: not f32"))?.to_vec();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ConvNetSpec {
        ConvNetSpec {
            input: (1, 8, 8),
            convs: vec![ConvStageSpec::same(4, 3, 2), ConvStageSpec::same(6, 3, 2)],
            fc_dims: vec![6 * 2 * 2, 16, 3],
        }
    }

    #[test]
    fn spec_shapes_and_validation() {
        let spec = tiny_spec();
        spec.validate().unwrap();
        assert_eq!(spec.stage_shapes(), vec![(1, 8, 8), (4, 4, 4), (6, 2, 2)]);
        assert_eq!(spec.conv_out_dim(), 24);
        let mut bad = tiny_spec();
        bad.fc_dims[0] = 25;
        assert!(bad.validate().is_err());
        let mut bad = tiny_spec();
        bad.convs[0].k = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn deep_mnist_paper_spec_shapes() {
        // TF-tutorial Deep MNIST: conv 5×5×32 pool2 → conv 5×5×64 pool2 →
        // fc 3136→1024→10 (paper Table 1's 3.22M FC params).
        let spec = ConvNetSpec {
            input: (1, 28, 28),
            convs: vec![ConvStageSpec::same(32, 5, 2), ConvStageSpec::same(64, 5, 2)],
            fc_dims: vec![3136, 1024, 10],
        };
        spec.validate().unwrap();
        assert_eq!(spec.conv_out_dim(), 64 * 7 * 7);
    }

    #[test]
    fn learns_tiny_synthetic_task() {
        // 3-class blobs rendered as 8×8 images with class-keyed quadrants.
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let spec = tiny_spec();
        let mut net = ConvNet::new(spec.clone(), &mut rng);
        let n = 60;
        let mut x = Vec::with_capacity(n * 64);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = (i % 3) as u32;
            for p in 0..64 {
                let (py, px) = (p / 8, p % 8);
                let on = match label {
                    0 => py < 4,
                    1 => px < 4,
                    _ => (py + px) % 2 == 0,
                };
                x.push(if on { 1.0 } else { -1.0 } + (rng.next_f32() - 0.5) * 0.3);
            }
            y.push(label);
        }
        let first = net.train_step(&x, &y, n, 0.05);
        let mut last = first;
        for _ in 0..40 {
            last = net.train_step(&x, &y, n, 0.05);
        }
        assert!(last < first * 0.6, "loss {first} → {last} did not drop");
        assert!(net.evaluate(&x, &y, n) > 0.8);
    }

    fn res_spec() -> ConvNetSpec {
        // conv0 → residual block (save → conv → conv+add) → global avg → fc
        ConvNetSpec {
            input: (1, 8, 8),
            convs: vec![
                ConvStageSpec::same(6, 3, 0),
                ConvStageSpec::plain(6, 3, 1, 1).saving_skip(),
                ConvStageSpec::plain(6, 3, 1, 1).adding_skip().global_avg_pool(),
            ],
            fc_dims: vec![6, 3],
        }
    }

    #[test]
    fn residual_spec_shapes_and_validation() {
        let spec = res_spec();
        spec.validate().unwrap();
        assert_eq!(spec.stage_shapes(), vec![(1, 8, 8), (6, 8, 8), (6, 8, 8), (6, 1, 1)]);
        assert_eq!(spec.conv_out_dim(), 6);

        let mut bad = res_spec();
        bad.convs[2].add_skip = false; // dangling save
        bad.fc_dims[0] = 6;
        assert!(bad.validate().unwrap_err().contains("dangling"));
        let mut bad = res_spec();
        bad.convs[1].save_skip = false; // add without save
        assert!(bad.validate().unwrap_err().contains("no pending skip"));
        let mut bad = res_spec();
        bad.convs[2].out_c = 4; // residual shape mismatch
        assert!(bad.validate().unwrap_err().contains("residual shapes differ"));
        let mut bad = res_spec();
        bad.convs[1].groups = 4; // 4 does not divide 6
        assert!(bad.validate().unwrap_err().contains("groups"));
        let mut bad = res_spec();
        bad.convs[2].pool_kind = PoolKind::GlobalAvg; // still fine: square
        bad.convs[0] = ConvStageSpec { pad: 0, ..ConvStageSpec::same(6, 2, 0) };
        // 8×8 k2 pad0 → 7×7; residual stages keep 7×7 (square) — still valid
        bad.fc_dims[0] = 6;
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn residual_forward_matches_manual_composition() {
        // Pin the stage semantics (save → conv → add → ReLU → global avg)
        // bit-for-bit against a hand-composed forward over the same weights.
        let mut rng = Xoshiro256pp::seed_from_u64(14);
        let mut net = ConvNet::new(res_spec(), &mut rng);
        let batch = 3;
        let x: Vec<f32> = (0..batch * 64).map(|i| (i as f32 * 0.13).sin()).collect();
        let logits = net.forward(&x, batch);

        let mut manual_convs: Vec<Conv2d> = net
            .spec
            .convs
            .iter()
            .zip(net.spec.stage_shapes())
            .map(|(s, (in_c, _, _))| Conv2d::new(s.out_c, in_c, s.k, s.stride, s.pad, &mut rng))
            .collect();
        for (m, c) in manual_convs.iter_mut().zip(&net.convs) {
            m.w = c.w.clone();
            m.b = c.b.clone();
        }
        let relu = |v: Vec<f32>| -> Vec<f32> { v.into_iter().map(|a| a.max(0.0)).collect() };
        let a0 = relu(manual_convs[0].forward(&x, batch, 8, 8));
        let snap = a0.clone();
        let a1 = relu(manual_convs[1].forward(&a0, batch, 8, 8));
        let mut a2 = manual_convs[2].forward(&a1, batch, 8, 8);
        for (a, &b) in a2.iter_mut().zip(&snap) {
            *a += b;
        }
        let a2 = relu(a2);
        // global average pool: per-(sample, channel) mean of the 8×8 map
        let mut pooled = vec![0.0f32; batch * 6];
        for bc in 0..batch * 6 {
            let mut acc = 0.0f32;
            for p in 0..64 {
                acc += a2[bc * 64 + p];
            }
            pooled[bc] = acc / 64.0;
        }
        let manual_logits = net.fcs[0].forward(&pooled, batch);
        assert_eq!(logits, manual_logits);
    }

    #[test]
    fn residual_net_training_reduces_loss() {
        let mut rng = Xoshiro256pp::seed_from_u64(15);
        let mut net = ConvNet::new(res_spec(), &mut rng);
        let x: Vec<f32> = (0..6 * 64).map(|i| (i as f32 * 0.13).sin()).collect();
        let y = vec![0u32, 1, 2, 0, 1, 2];
        let first = net.train_step(&x, &y, 6, 0.05);
        let mut last = first;
        for _ in 0..40 {
            last = net.train_step(&x, &y, 6, 0.05);
        }
        assert!(last < first * 0.6, "residual net loss {first} → {last} did not drop");
    }

    #[test]
    fn masked_training_confines_weights() {
        use crate::mask::blockdiag::off_block_mass;
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let spec = tiny_spec();
        // mask conv1's 6×(4·9)=6×36 filter matrix and fc0's 16×24 matrix
        let conv_mask = MpdMask::generate(6, 36, 3, &mut rng);
        let fc_mask = MpdMask::generate(16, 24, 4, &mut rng);
        let (cm, fm) = (conv_mask.clone(), fc_mask.clone());
        let mut net = ConvNet::new(spec, &mut rng)
            .with_masks(vec![None, Some(conv_mask)], vec![Some(fc_mask), None]);
        let x: Vec<f32> = (0..5 * 64).map(|i| (i as f32 * 0.17).sin()).collect();
        let y = vec![0u32, 1, 2, 0, 1];
        for _ in 0..5 {
            net.train_step(&x, &y, 5, 0.05);
        }
        assert_eq!(off_block_mass(&cm.unpermute(&net.convs[1].w), &cm.layout), 0.0);
        assert_eq!(off_block_mass(&fm.unpermute(&net.fcs[0].w), &fm.layout), 0.0);
        assert!(net.effective_param_count() < net.param_count());
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let spec = tiny_spec();
        let a = ConvNet::new(spec.clone(), &mut rng);
        let mut b = ConvNet::new(spec, &mut rng);
        let tensors = a.named_tensors();
        assert_eq!(tensors.len(), 2 * 2 + 2 * 2);
        b.load_tensors(&tensors).unwrap();
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.w, cb.w);
            assert_eq!(ca.b, cb.b);
        }
        for (la, lb) in a.fcs.iter().zip(&b.fcs) {
            assert_eq!(la.w, lb.w);
            assert_eq!(la.b, lb.b);
        }
        // bad shape rejected
        let mut bad = a.named_tensors();
        bad[0] = NamedTensor::f32("conv0.w", vec![1, 1, 1, 1], vec![0.0]);
        assert!(b.load_tensors(&bad).is_err());
    }
}
