//! Native multi-layer perceptron — the LeNet-300-100 workhorse for the
//! Fig. 4 experiments (100-mask sweep, non-permuted ablation) and the CPU
//! cross-check of the JAX/AOT training path.

use crate::mask::mask::MpdMask;
use crate::mask::prng::Xoshiro256pp;
use crate::nn::layer::{accuracy, softmax_xent, Linear, Relu};

/// MLP with ReLU between layers and raw logits at the output.
pub struct Mlp {
    pub dims: Vec<usize>,
    pub layers: Vec<Linear>,
    relus: Vec<Relu>,
}

impl Mlp {
    /// `dims = [in, h1, ..., out]`.
    pub fn new(dims: &[usize], rng: &mut Xoshiro256pp) -> Self {
        assert!(dims.len() >= 2);
        let layers = dims.windows(2).map(|w| Linear::new(w[1], w[0], rng)).collect::<Vec<_>>();
        let relus = (0..dims.len() - 2).map(|_| Relu::new()).collect();
        Self { dims: dims.to_vec(), layers, relus }
    }

    /// Attach MPD masks to selected layers: `masks[i]` applies to layer `i`
    /// (None = dense). Per the paper, LeNet-300-100 masks FC1 (784×300) and
    /// FC2 (300×100), leaving the 10-way classifier dense.
    pub fn with_masks(mut self, masks: Vec<Option<MpdMask>>) -> Self {
        assert_eq!(masks.len(), self.layers.len());
        let layers = std::mem::take(&mut self.layers);
        self.layers = layers
            .into_iter()
            .zip(masks)
            .map(|(l, m)| match m {
                Some(mask) => l.with_mask(mask),
                None => l,
            })
            .collect();
        self
    }

    pub fn forward(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        let n = self.layers.len();
        let mut act = self.layers[0].forward(x, batch);
        for i in 1..n {
            act = self.relus[i - 1].forward(&act);
            act = self.layers[i].forward(&act, batch);
        }
        act
    }

    /// One SGD step on a batch; returns the loss.
    pub fn train_step(&mut self, x: &[f32], labels: &[u32], batch: usize, lr: f32) -> f32 {
        let classes = *self.dims.last().unwrap();
        let logits = self.forward(x, batch);
        let (loss, mut grad) = softmax_xent(&logits, labels, batch, classes);
        let n = self.layers.len();
        for i in (0..n).rev() {
            grad = self.layers[i].backward(&grad);
            if i > 0 {
                grad = self.relus[i - 1].backward(&grad);
            }
        }
        for l in &mut self.layers {
            l.sgd_step(lr);
        }
        loss
    }

    /// Accuracy over a dataset slice.
    pub fn evaluate(&mut self, x: &[f32], labels: &[u32], batch: usize) -> f64 {
        let classes = *self.dims.last().unwrap();
        let logits = self.forward(x, batch);
        accuracy(&logits, labels, batch, classes)
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Surviving params after masking — the paper's Table 1 "Number of
    /// Parameters in FC" comparison.
    pub fn effective_param_count(&self) -> usize {
        self.layers.iter().map(|l| l.effective_param_count()).sum()
    }

    /// Named parameter tensors for checkpointing: `fc{i}.w`, `fc{i}.b`.
    pub fn named_params(&self) -> Vec<(String, Vec<usize>, &[f32])> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("fc{i}.w"), vec![l.out_dim, l.in_dim], l.w.as_slice()));
            out.push((format!("fc{i}.b"), vec![l.out_dim], l.b.as_slice()));
        }
        out
    }

    /// Load parameters by name (inverse of [`Self::named_params`]).
    pub fn load_params(&mut self, params: &[(String, Vec<usize>, Vec<f32>)]) -> Result<(), String> {
        for (name, shape, data) in params {
            let (kind, idx) = parse_param_name(name)?;
            let l = self.layers.get_mut(idx).ok_or_else(|| format!("no layer {idx}"))?;
            match kind {
                "w" => {
                    if *shape != vec![l.out_dim, l.in_dim] {
                        return Err(format!("{name}: shape {shape:?} != [{}, {}]", l.out_dim, l.in_dim));
                    }
                    l.w = data.clone();
                }
                "b" => {
                    if *shape != vec![l.out_dim] {
                        return Err(format!("{name}: shape {shape:?} != [{}]", l.out_dim));
                    }
                    l.b = data.clone();
                }
                other => return Err(format!("unknown param kind {other}")),
            }
        }
        Ok(())
    }
}

fn parse_param_name(name: &str) -> Result<(&str, usize), String> {
    let rest = name.strip_prefix("fc").ok_or_else(|| format!("bad param name {name}"))?;
    let (idx, kind) = rest.split_once('.').ok_or_else(|| format!("bad param name {name}"))?;
    Ok((kind, idx.parse().map_err(|_| format!("bad layer index in {name}"))?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::blockdiag::off_block_mass;

    fn rng(seed: u64) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(seed)
    }

    /// Tiny two-gaussian-blob classification task.
    fn blob_data(n: usize, dim: usize, rng: &mut Xoshiro256pp) -> (Vec<f32>, Vec<u32>) {
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = (i % 2) as u32;
            let center = if label == 0 { -1.0 } else { 1.0 };
            for _ in 0..dim {
                x.push((center + rng.next_normal() * 0.3) as f32);
            }
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn learns_blobs() {
        let mut r = rng(1);
        let mut mlp = Mlp::new(&[4, 16, 2], &mut r);
        let (x, y) = blob_data(64, 4, &mut r);
        let first_loss = mlp.train_step(&x, &y, 64, 0.1);
        let mut last = first_loss;
        for _ in 0..50 {
            last = mlp.train_step(&x, &y, 64, 0.1);
        }
        assert!(last < first_loss * 0.5, "loss {first_loss} → {last} did not drop");
        assert!(mlp.evaluate(&x, &y, 64) > 0.95);
    }

    #[test]
    fn masked_mlp_learns_and_stays_masked() {
        let mut r = rng(2);
        let mask1 = MpdMask::generate(16, 8, 4, &mut r);
        let layout1 = mask1.layout.clone();
        let m1 = mask1.clone();
        let mut mlp = Mlp::new(&[8, 16, 2], &mut r).with_masks(vec![Some(mask1), None]);
        let (x, y) = blob_data(64, 8, &mut r);
        for _ in 0..60 {
            mlp.train_step(&x, &y, 64, 0.1);
        }
        assert!(mlp.evaluate(&x, &y, 64) > 0.9);
        // masked weights, unpermuted, must be exactly block diagonal
        let star = m1.unpermute(&mlp.layers[0].w);
        assert_eq!(off_block_mass(&star, &layout1), 0.0);
    }

    #[test]
    fn param_counts() {
        let mut r = rng(3);
        // LeNet-300-100 dims: dense params (784·300+300)+(300·100+100)+(100·10+10)
        let mlp = Mlp::new(&[784, 300, 100, 10], &mut r);
        assert_eq!(mlp.param_count(), 784 * 300 + 300 + 300 * 100 + 100 + 100 * 10 + 10);
        // with 10-block masks on fc1+fc2 the paper's 272k → 27.2k FC weights
        let mask1 = MpdMask::generate(300, 784, 10, &mut r);
        let mask2 = MpdMask::generate(100, 300, 10, &mut r);
        let nnz = mask1.nnz() + mask2.nnz();
        let mlp = Mlp::new(&[784, 300, 100, 10], &mut r).with_masks(vec![Some(mask1), Some(mask2), None]);
        assert_eq!(
            mlp.effective_param_count(),
            nnz + 300 + 100 + 100 * 10 + 10
        );
        // ≈ 10× compression of the masked FC weights
        let dense_fc = 784 * 300 + 300 * 100;
        assert!((dense_fc as f64 / nnz as f64 - 10.0).abs() < 0.2);
    }

    #[test]
    fn named_params_roundtrip() {
        let mut r = rng(4);
        let mut a = Mlp::new(&[6, 5, 3], &mut r);
        let b = Mlp::new(&[6, 5, 3], &mut r);
        let saved: Vec<(String, Vec<usize>, Vec<f32>)> =
            b.named_params().into_iter().map(|(n, s, d)| (n, s, d.to_vec())).collect();
        a.load_params(&saved).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.w, lb.w);
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    fn load_params_rejects_bad_shapes() {
        let mut r = rng(5);
        let mut a = Mlp::new(&[6, 5, 3], &mut r);
        let bad = vec![("fc0.w".to_string(), vec![5usize, 7], vec![0.0f32; 35])];
        assert!(a.load_params(&bad).is_err());
        let unknown = vec![("fc9.w".to_string(), vec![5usize, 6], vec![0.0f32; 30])];
        assert!(a.load_params(&unknown).is_err());
    }
}
