"""L2 model correctness: shapes, masked training dynamics, and the packed
(Fig. 3) inference path vs the dense reference — the eq.-2 equivalence that
everything downstream relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from tests.mpd_ref import Mask, interlayer_gather


def _mask_np(rng, out_dim, in_dim, k):
    m = Mask(out_dim, in_dim, k, rng)
    return m, jnp.asarray(m.dense())


# ---------------------------------------------------------------------------
# LeNet
# ---------------------------------------------------------------------------

def test_lenet_forward_shapes():
    p = model.lenet_init(0)
    x = jnp.zeros((7, 784), jnp.float32)
    y = model.lenet_forward_dense(p, x)
    assert y.shape == (7, 10)


def test_lenet_masked_equals_dense_on_masked_weights():
    rng = np.random.default_rng(0)
    p = model.lenet_init(1)
    m1r, m1 = _mask_np(rng, 300, 784, 10)
    m2r, m2 = _mask_np(rng, 100, 300, 10)
    x = jnp.asarray(rng.normal(size=(5, 784)).astype(np.float32))
    # masked forward == dense forward on pre-masked weights
    y_masked = model.lenet_forward_masked(p, m1, m2, x)
    p_masked = p._replace(w1=p.w1 * m1, w2=p.w2 * m2)
    y_dense = model.lenet_forward_dense(p_masked, x)
    np.testing.assert_allclose(y_masked, y_dense, rtol=1e-4, atol=1e-4)


def test_lenet_train_step_decreases_loss_and_keeps_mask():
    rng = np.random.default_rng(1)
    p = model.lenet_init(2)
    _, m1 = _mask_np(rng, 300, 784, 10)
    _, m2 = _mask_np(rng, 100, 300, 10)
    x = jnp.asarray(rng.normal(size=(50, 784)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=50).astype(np.int32))
    lr = jnp.float32(0.3)
    losses = []
    for _ in range(40):
        p, loss = model.lenet_train_step(p, m1, m2, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])
    # weights confined to the mask
    assert np.all(np.asarray(p.w1)[np.asarray(m1) == 0.0] == 0.0)
    assert np.all(np.asarray(p.w2)[np.asarray(m2) == 0.0] == 0.0)


def test_lenet_packed_inference_matches_dense():
    """The cross-language tile-space contract (mpd_ref) against the actual
    packed entrypoint — the strongest eq.-2 end-to-end check in python."""
    rng = np.random.default_rng(2)
    p = model.lenet_init(3)
    k = 10
    mask1 = Mask(300, 784, k, rng)
    mask2 = Mask(100, 300, k, rng)
    m1 = jnp.asarray(mask1.dense())
    m2 = jnp.asarray(mask2.dense())
    pm = p._replace(w1=p.w1 * m1, w2=p.w2 * m2,
                    b1=jnp.asarray(rng.normal(size=300).astype(np.float32)),
                    b2=jnp.asarray(rng.normal(size=100).astype(np.float32)))
    x = rng.normal(size=(4, 784)).astype(np.float32)
    want = model.lenet_forward_dense(pm, jnp.asarray(x))

    # coordinator-side packing (numpy reference)
    xp = jnp.asarray(mask1.x_to_tiles(x))
    wb1 = jnp.asarray(mask1.packed_blocks(np.asarray(pm.w1)))
    b1p = jnp.asarray(mask1.bias_to_tiles(np.asarray(pm.b1)))
    g12 = jnp.asarray(interlayer_gather(mask1, mask2))
    wb2 = jnp.asarray(mask2.packed_blocks(np.asarray(pm.w2)))
    b2p = jnp.asarray(mask2.bias_to_tiles(np.asarray(pm.b2)))
    g2o = jnp.asarray(mask2.out_tiles_to_logical_gather())
    got = model.lenet_infer_packed(xp, wb1, b1p, g12, wb2, b2p, g2o, pm.w3, pm.b3)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv nets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(model.SPECS))
def test_conv_shapes_and_flatdim(name):
    spec = model.SPECS[name]
    params = model.conv_init(spec, 0)
    nmask = sum(spec.masked_fc)
    masks = [jnp.ones(s, jnp.float32) for s, mk in zip(spec.fc_shapes(), spec.masked_fc) if mk]
    assert len(masks) == nmask
    c, h, w = spec.in_shape
    x = jnp.zeros((3, c, h, w), jnp.float32)
    y = model.conv_forward(spec, params, masks, x)
    assert y.shape == (3, spec.classes)


def test_tiny_alexnet_flat_dim():
    # 32×32 → conv s2 → 16 → pool → 8 → conv s1 → 8 → pool → 4; 64ch → 1024
    assert model.TINY_ALEXNET.flat_dim() == 1024


@pytest.mark.parametrize("name", list(model.SPECS))
def test_conv_train_step_decreases_loss(name):
    spec = model.SPECS[name]
    rng = np.random.default_rng(4)
    params = model.conv_init(spec, 1)
    masks = []
    for s, mk in zip(spec.fc_shapes(), spec.masked_fc):
        if mk:
            k = min(8, min(s))
            masks.append(jnp.asarray(Mask(s[0], s[1], k, rng).dense()))
    c, h, w = spec.in_shape
    x = jnp.asarray(rng.normal(size=(16, c, h, w)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, spec.classes, size=16).astype(np.int32))
    lr = jnp.float32(0.01)
    losses = []
    for _ in range(8):
        params, loss = model.conv_train_step(spec, params, masks, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    # masked FC weights stay confined
    nconv = 2 * len(spec.convs)
    mi = 0
    for li, mk in enumerate(spec.masked_fc):
        if mk:
            wn = np.asarray(params[nconv + 2 * li])
            mn = np.asarray(masks[mi])
            assert np.all(wn[mn == 0.0] == 0.0)
            mi += 1


def test_softmax_xent_sane():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    assert float(model.softmax_xent(logits, labels)) < 1e-3
    assert float(model.softmax_xent(logits, jnp.asarray([1, 0], jnp.int32))) > 5.0
