"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/block-counts; the CORE correctness signal of the
compile path (pallas interpret=True on CPU; the same kernels lower to Mosaic
on TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.blockdiag_matmul import blockdiag_matmul, mxu_util_estimate, vmem_bytes
from compile.kernels.masked_matmul import masked_linear, masked_matmul
from compile.kernels.ref import blockdiag_matmul_ref, masked_matmul_ref


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# blockdiag_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 8),
    k=st.integers(1, 12),
    ob=st.integers(1, 16),
    ib=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_blockdiag_matches_ref(b, k, ob, ib, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, k * ib)
    w = rand(rng, k, ob, ib)
    got = blockdiag_matmul(x, w)
    want = blockdiag_matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_blockdiag_paper_shape_lenet_fc1():
    # LeNet fc1 at k=10: IB=79, OB=30 (ragged 784×300 padded to tiles)
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 10 * 79)
    w = rand(rng, 10, 30, 79)
    np.testing.assert_allclose(
        blockdiag_matmul(x, w), blockdiag_matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_blockdiag_zero_padding_is_exact():
    # zero-padded rows/cols contribute exactly nothing
    rng = np.random.default_rng(1)
    k, ob, ib = 3, 4, 5
    w = rand(rng, k, ob, ib)
    w = w.at[:, 2:, :].set(0.0)  # padded output rows
    x = rand(rng, 2, k * ib)
    y = blockdiag_matmul(x, w)
    y = np.asarray(y).reshape(2, k, ob)
    assert np.all(y[:, :, 2:] == 0.0)


def test_blockdiag_independence_of_blocks():
    # perturbing block j's input only changes block j's output — the paper's
    # "no dependence on any other blocks" claim, asserted numerically.
    rng = np.random.default_rng(2)
    k, ob, ib, b = 4, 3, 5, 2
    w = rand(rng, k, ob, ib)
    x = rand(rng, b, k * ib)
    y0 = np.asarray(blockdiag_matmul(x, w))
    x2 = np.array(x)
    x2[:, 1 * ib:2 * ib] += 1.0  # perturb block 1 only
    y1 = np.asarray(blockdiag_matmul(jnp.asarray(x2), w))
    diff = (y0 != y1).reshape(b, k, ob)
    assert diff[:, 1, :].any()
    assert not diff[:, [0, 2, 3], :].any()


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 8),
    out_tiles=st.integers(1, 4),
    ot=st.sampled_from([1, 2, 4, 8, 16]),
    inp=st.integers(1, 48),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matches_ref(b, out_tiles, ot, inp, density, seed):
    rng = np.random.default_rng(seed)
    out = out_tiles * ot
    x = rand(rng, b, inp)
    w = rand(rng, out, inp)
    m = jnp.asarray((rng.random((out, inp)) < density).astype(np.float32))
    got = masked_matmul(x, w, m, out_tile=ot)
    want = masked_matmul_ref(x, w, m)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_masked_linear_gradients_respect_mask():
    rng = np.random.default_rng(3)
    x = rand(rng, 4, 12)
    w = rand(rng, 8, 12)
    m = jnp.asarray((rng.random((8, 12)) < 0.3).astype(np.float32))

    def loss(w):
        return jnp.sum(masked_linear(x, w, m) ** 2)

    g = jax.grad(loss)(w)
    # gradient is exactly zero off-mask: updates can never leak off-block
    assert np.all(np.asarray(g)[np.asarray(m) == 0.0] == 0.0)
    # and matches the reference gradient on-mask
    gr = jax.grad(lambda w: jnp.sum(masked_matmul_ref(x, w, m) ** 2))(w)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)


def test_masked_full_mask_equals_dense():
    rng = np.random.default_rng(4)
    x = rand(rng, 3, 10)
    w = rand(rng, 6, 10)
    m = jnp.ones((6, 10), jnp.float32)
    np.testing.assert_allclose(masked_matmul(x, w, m, out_tile=6), x @ w.T, rtol=1e-5, atol=1e-5)


def test_masked_empty_mask_is_zero():
    rng = np.random.default_rng(5)
    x = rand(rng, 3, 10)
    w = rand(rng, 6, 10)
    m = jnp.zeros((6, 10), jnp.float32)
    assert np.all(np.asarray(masked_matmul(x, w, m, out_tile=2)) == 0.0)


# ---------------------------------------------------------------------------
# roofline estimators (structure-level checks; interpret=True gives no
# meaningful wallclock — see DESIGN.md §7)
# ---------------------------------------------------------------------------

def test_vmem_budget_for_paper_shapes():
    # AlexNet FC6 at 12.5% (k=8): blocks are 512×2048 → must fit 16 MiB VMEM
    assert vmem_bytes(batch=64, k=8, ob=512, ib=2048) < 16 * 2**20
    # LeNet fc1 blocks trivially fit
    assert vmem_bytes(batch=256, k=10, ob=30, ib=79) < 2**20


def test_mxu_estimate_monotone_in_alignment():
    # MXU-aligned block dims waste nothing; tiny blocks waste almost all lanes
    assert mxu_util_estimate(128, 128, 128) == 1.0
    assert mxu_util_estimate(1, 30, 79) < 0.01
    aligned = mxu_util_estimate(128, 512, 2048)
    assert aligned == 1.0
