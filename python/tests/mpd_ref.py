"""Numpy reference implementation of the MPD mask / tile-space contract.

This mirrors ``rust/src/mask`` + the packing performed by the rust
coordinator before invoking the ``lenet_infer_packed_*`` artifacts. It exists
so python tests can validate the packed-inference executable end-to-end
against the dense computation, pinning the cross-language contract:

* ragged block partition: base = n//k, remainder spread over leading blocks
* mask M = P_row · B · P_col (entry (r,c) kept iff the un-permuted coordinate
  lies on a diagonal block)
* eq. 2 re-blocking W* = P_rowᵀ · W̄ · P_colᵀ
* uniform zero-padded tiles: IB = ceil(in/k), OB = ceil(out/k)
* tile-space activations/bias and inter-layer gather indices
"""

import numpy as np


def partition(n: int, k: int):
    """[(start, len)] spans; sizes differ by ≤1, remainder on leading blocks."""
    base, rem = divmod(n, k)
    spans, start = [], 0
    for b in range(k):
        ln = base + (1 if b < rem else 0)
        spans.append((start, ln))
        start += ln
    return spans


class Mask:
    """An MPD mask in factored form (forward-map convention: dest(i)=map[i])."""

    def __init__(self, out_dim: int, in_dim: int, k: int, rng: np.random.Generator):
        self.out_dim, self.in_dim, self.k = out_dim, in_dim, k
        self.rs = partition(out_dim, k)
        self.cs = partition(in_dim, k)
        self.p_row = rng.permutation(out_dim)  # dest index per source
        self.p_col = rng.permutation(in_dim)

    def dense(self) -> np.ndarray:
        m = np.zeros((self.out_dim, self.in_dim), np.float32)
        for (r0, rl), (c0, cl) in zip(self.rs, self.cs):
            rows = self.p_row[r0:r0 + rl]
            cols = self.p_col[c0:c0 + cl]
            m[np.ix_(rows, cols)] = 1.0
        return m

    def unpermute(self, w_masked: np.ndarray) -> np.ndarray:
        """eq. 2: W*[r', c'] = W̄[p_row[r'], p_col[c']] — block diagonal."""
        return w_masked[np.ix_(self.p_row, self.p_col)]

    def tile_dims(self):
        ib = -(-self.in_dim // self.k)
        ob = -(-self.out_dim // self.k)
        return ob, ib

    def packed_blocks(self, w_masked: np.ndarray) -> np.ndarray:
        """[K, OB, IB] zero-padded blocks of W*."""
        star = self.unpermute(w_masked)
        ob, ib = self.tile_dims()
        out = np.zeros((self.k, ob, ib), np.float32)
        for b, ((r0, rl), (c0, cl)) in enumerate(zip(self.rs, self.cs)):
            out[b, :rl, :cl] = star[r0:r0 + rl, c0:c0 + cl]
        return out

    def x_to_tiles(self, x: np.ndarray) -> np.ndarray:
        """[B, in] logical activations → [B, K*IB] layer-input tile space."""
        _, ib = self.tile_dims()
        xp = x[:, self.p_col]  # x'[c'] = x[p_col[c']]
        out = np.zeros((x.shape[0], self.k * ib), np.float32)
        for b, (c0, cl) in enumerate(self.cs):
            out[:, b * ib:b * ib + cl] = xp[:, c0:c0 + cl]
        return out

    def bias_to_tiles(self, bias: np.ndarray) -> np.ndarray:
        """[out] logical bias → [K*OB] output tile space (pads are 0)."""
        ob, _ = self.tile_dims()
        bp = bias[self.p_row]  # b'[r'] = b[p_row[r']]
        out = np.zeros(self.k * ob, np.float32)
        for b, (r0, rl) in enumerate(self.rs):
            out[b * ob:b * ob + rl] = bp[r0:r0 + rl]
        return out

    def out_tiles_to_logical_gather(self) -> np.ndarray:
        """i32 gather g: logical[c] = tiles[g[c]]."""
        ob, _ = self.tile_dims()
        inv_row = np.argsort(self.p_row)  # r' = inv_row[logical]
        g = np.zeros(self.out_dim, np.int32)
        for c in range(self.out_dim):
            rp = inv_row[c]
            for b, (r0, rl) in enumerate(self.rs):
                if r0 <= rp < r0 + rl:
                    g[c] = b * ob + (rp - r0)
                    break
        return g


def interlayer_gather(prev: Mask, nxt: Mask) -> np.ndarray:
    """i32 gather from `prev`'s output tile space into `nxt`'s input tile
    space: next_in_tiles[j] = prev_out_tiles[g[j]]. Padded positions of the
    next layer's input tiles may point anywhere (their weight columns are
    zero-padded), we point them at slot 0."""
    assert prev.out_dim == nxt.in_dim
    ob_p, _ = prev.tile_dims()
    _, ib_n = nxt.tile_dims()
    inv_row_p = np.argsort(prev.p_row)
    g = np.zeros(nxt.k * ib_n, np.int32)
    for b, (c0, cl) in enumerate(nxt.cs):
        for i in range(cl):
            logical = nxt.p_col[c0 + i]          # neuron index
            rp = inv_row_p[logical]              # prev block-row position
            for pb, (r0, rl) in enumerate(prev.rs):
                if r0 <= rp < r0 + rl:
                    g[b * ib_n + i] = pb * ob_p + (rp - r0)
                    break
    return g
