"""AOT artifact pipeline checks: lowering determinism, metadata consistency,
and HLO-text round-trip executability through xla_client (the same parser
path the rust runtime uses)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def out_dir():
    with tempfile.TemporaryDirectory() as d:
        aot.lenet_entries(d)
        yield d


def test_meta_matches_artifacts(out_dir):
    names = [f[: -len(".hlo.txt")] for f in os.listdir(out_dir) if f.endswith(".hlo.txt")]
    assert len(names) >= 7
    for name in names:
        with open(os.path.join(out_dir, f"{name}.meta.json")) as f:
            meta = json.load(f)
        assert meta["name"] == name
        assert all("shape" in t and "dtype" in t for t in meta["inputs"])
        assert all(t["dtype"] in ("f32", "i32") for t in meta["inputs"] + meta["outputs"])


def test_train_step_meta_shapes(out_dir):
    with open(os.path.join(out_dir, "lenet_train_step_b50.meta.json")) as f:
        meta = json.load(f)
    shapes = [tuple(t["shape"]) for t in meta["inputs"]]
    assert shapes[0] == (300, 784)      # w1
    assert shapes[6] == (300, 784)      # m1
    assert shapes[8] == (50, 784)       # x
    assert tuple(meta["inputs"][9]["shape"]) == (50,)  # labels
    assert meta["inputs"][9]["dtype"] == "i32"
    # outputs: 6 params + loss
    assert len(meta["outputs"]) == 7
    assert tuple(meta["outputs"][6]["shape"]) == ()


def test_lowering_is_deterministic():
    args = [aot._spec((10, 4)), aot._spec((4,))]
    fn = lambda w, b: (w.sum(0) + b,)
    a = aot.to_hlo_text(jax.jit(fn).lower(*args))
    b = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert a == b


def test_hlo_text_parses_back(out_dir):
    """Parse the artifact text back through XLA's HLO text parser — the same
    path `HloModuleProto::from_text_file` uses in the rust runtime. (Numeric
    equivalence of the parsed module is asserted by the rust integration
    tests, which execute every artifact against the native engine.)"""
    for name in ("lenet_infer_b1", "lenet_train_step_b50", "lenet_infer_packed_k10_b32"):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path) as f:
            text = f.read()
        hlo = xc._xla.hlo_module_from_text(text)
        proto = hlo.as_serialized_hlo_module_proto()
        assert len(proto) > 100
        # parameter count in the entry computation matches the meta
        with open(os.path.join(out_dir, f"{name}.meta.json")) as f:
            meta = json.load(f)
        entry = text[text.index("ENTRY"):]
        entry_head = entry[: entry.index("\n\n")] if "\n\n" in entry else entry
        nparams = entry_head.count("= f32[") + entry_head.count("= s32[")
        nparams = sum(
            1 for line in entry_head.splitlines() if "parameter(" in line
        )
        assert nparams == len(meta["inputs"]), name
