"""L2: the MPDCompress model zoo in JAX — build-time only, never imported at
runtime.

Models (paper §3): LeNet-300-100 (MLP), Deep-MNIST-lite, CIFAR-lite and
TinyAlexNet (conv nets — scaled-down per DESIGN.md §2; the FC *topology* and
masking plan match the paper, the channel/FC widths are shrunk to what a
1-core CPU testbed can train).

Everything here is expressed as pure functions over flat parameter tuples so
that ``aot.py`` can lower each entrypoint to a single HLO module whose
parameter list the rust coordinator can feed positionally:

* ``*_train_step``: (params..., masks..., x, y, lr) -> (params'..., loss)
  — one SGD step. Masks are *inputs*, so one compiled executable serves every
  mask instantiation (the Fig. 4(a) hundred-mask sweep re-uses one artifact).
  Per Algorithm 1 the binary mask multiplies the weights on the forward pass
  (via the L1 ``masked_linear`` Pallas kernel) and is re-applied to the
  updated weights after the gradient step.
* ``*_infer``: (params..., x) -> logits — masked/dense inference.
* ``lenet_infer_packed``: tile-space block-diagonal inference built on the
  L1 ``blockdiag_matmul`` Pallas kernel (paper Fig. 3), with inter-layer
  permutations supplied as gather-index inputs so the same executable serves
  any mask.
"""

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.blockdiag_matmul import blockdiag_matmul
from compile.kernels.masked_matmul import masked_linear


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def he_init(key, out_dim: int, in_dim: int) -> jnp.ndarray:
    return jax.random.normal(key, (out_dim, in_dim), jnp.float32) * jnp.sqrt(2.0 / in_dim)


# --------------------------------------------------------------------------
# LeNet-300-100 (MLP 784-300-100-10), masks on fc1 + fc2 (paper §3.1)
# --------------------------------------------------------------------------

LENET_DIMS = (784, 300, 100, 10)


class LenetParams(NamedTuple):
    w1: jnp.ndarray  # [300, 784]
    b1: jnp.ndarray
    w2: jnp.ndarray  # [100, 300]
    b2: jnp.ndarray
    w3: jnp.ndarray  # [10, 100]
    b3: jnp.ndarray


def lenet_init(seed: int = 0) -> LenetParams:
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    d = LENET_DIMS
    return LenetParams(
        he_init(ks[0], d[1], d[0]), jnp.zeros(d[1], jnp.float32),
        he_init(ks[1], d[2], d[1]), jnp.zeros(d[2], jnp.float32),
        he_init(ks[2], d[3], d[2]), jnp.zeros(d[3], jnp.float32),
    )


def lenet_forward_masked(p: LenetParams, m1: jnp.ndarray, m2: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Training-mode forward: masked FC1/FC2 via the L1 Pallas kernel."""
    h = jax.nn.relu(masked_linear(x, p.w1, m1) + p.b1)
    h = jax.nn.relu(masked_linear(h, p.w2, m2) + p.b2)
    return h @ p.w3.T + p.b3


def lenet_forward_dense(p: LenetParams, x: jnp.ndarray) -> jnp.ndarray:
    """Inference on stored (already-masked or dense) weights."""
    h = jax.nn.relu(x @ p.w1.T + p.b1)
    h = jax.nn.relu(h @ p.w2.T + p.b2)
    return h @ p.w3.T + p.b3


def lenet_train_step(p: LenetParams, m1, m2, x, y, lr):
    """One SGD step; mask re-applied to updated weights (Algorithm 1 l.14)."""

    def loss_fn(p):
        return softmax_xent(lenet_forward_masked(p, m1, m2, x), y)

    loss, g = jax.value_and_grad(loss_fn)(p)
    new = LenetParams(
        (p.w1 - lr * g.w1) * m1, p.b1 - lr * g.b1,
        (p.w2 - lr * g.w2) * m2, p.b2 - lr * g.b2,
        p.w3 - lr * g.w3, p.b3 - lr * g.b3,
    )
    return new, loss


def lenet_infer_packed(xp, wb1, b1p, g12, wb2, b2p, g2o, w3f, b3):
    """Fig.-3 packed inference in tile space (see DESIGN.md):

    xp   [B, K1*IB1]  input already gathered into layer-1 tile space
    wb1  [K1, OB1, IB1] packed padded blocks of W1*
    b1p  [K1*OB1]     bias in layer-1 output tile space
    g12  [K2*IB2] i32 gather: layer-1 out tile space → layer-2 in tile space
    wb2, b2p          likewise for layer 2
    g2o  [100] i32    gather: layer-2 out tile space → logical order
    w3f  [10, 100]    dense head (columns pre-folded by the coordinator)
    b3   [10]
    """
    h = jax.nn.relu(blockdiag_matmul(xp, wb1) + b1p)
    h = jnp.take(h, g12, axis=1)
    h = jax.nn.relu(blockdiag_matmul(h, wb2) + b2p)
    h = jnp.take(h, g2o, axis=1)
    return h @ w3f.T + b3


# --------------------------------------------------------------------------
# Conv nets: generic spec covering Deep-MNIST-lite / CIFAR-lite / TinyAlexNet
# --------------------------------------------------------------------------

class ConvSpec(NamedTuple):
    """One conv stage: 3×3-or-5×5 same conv + ReLU + optional 2×2 maxpool."""
    out_c: int
    kernel: int
    stride: int
    pool: bool


class NetSpec(NamedTuple):
    name: str
    in_shape: tuple  # (C, H, W)
    convs: tuple     # tuple[ConvSpec]
    fc_dims: tuple   # hidden+output FC dims after flatten
    masked_fc: tuple # bool per FC layer
    classes: int

    def flat_dim(self) -> int:
        c, h, w = self.in_shape
        for cs in self.convs:
            h = (h + cs.stride - 1) // cs.stride
            w = (w + cs.stride - 1) // cs.stride
            if cs.pool:
                h //= 2
                w //= 2
            c = cs.out_c
        return c * h * w

    def fc_shapes(self):
        dims = (self.flat_dim(),) + tuple(self.fc_dims)
        return [(dims[i + 1], dims[i]) for i in range(len(self.fc_dims))]


# paper's Deep MNIST (conv32-conv64-fc1024-fc10) scaled ~4× down
DEEP_MNIST_LITE = NetSpec(
    name="deep_mnist",
    in_shape=(1, 28, 28),
    convs=(ConvSpec(8, 5, 1, True), ConvSpec(16, 5, 1, True)),
    fc_dims=(256, 10),
    masked_fc=(True, False),
    classes=10,
)

# TF-tutorial CIFAR net (conv-conv-fc384-fc192-fc10) scaled down
CIFAR_LITE = NetSpec(
    name="cifar10",
    in_shape=(3, 32, 32),
    convs=(ConvSpec(16, 5, 1, True), ConvSpec(32, 5, 1, True)),
    fc_dims=(192, 96, 10),
    masked_fc=(True, True, False),
    classes=10,
)

# AlexNet topology (5 conv → 3 masked FC) scaled to this testbed; all three
# FC layers masked exactly as the paper masks FC6/FC7/FC8.
TINY_ALEXNET = NetSpec(
    name="tiny_alexnet",
    in_shape=(3, 32, 32),
    convs=(ConvSpec(16, 3, 2, True), ConvSpec(64, 3, 1, True)),
    fc_dims=(256, 256, 16),
    masked_fc=(True, True, True),
    classes=16,
)

SPECS = {s.name: s for s in (DEEP_MNIST_LITE, CIFAR_LITE, TINY_ALEXNET)}


def conv_init(spec: NetSpec, seed: int = 0):
    """Flat param list: [cw0, cb0, cw1, cb1, ..., fw0, fb0, ...]."""
    key = jax.random.PRNGKey(seed)
    params = []
    in_c = spec.in_shape[0]
    for cs in spec.convs:
        key, k = jax.random.split(key)
        fan_in = in_c * cs.kernel * cs.kernel
        params.append(jax.random.normal(k, (cs.out_c, in_c, cs.kernel, cs.kernel), jnp.float32)
                      * jnp.sqrt(2.0 / fan_in))
        params.append(jnp.zeros((cs.out_c,), jnp.float32))
        in_c = cs.out_c
    for (od, idim) in spec.fc_shapes():
        key, k = jax.random.split(key)
        params.append(he_init(k, od, idim))
        params.append(jnp.zeros((od,), jnp.float32))
    return params


def conv_forward(spec: NetSpec, params: Sequence[jnp.ndarray], masks: Sequence[jnp.ndarray], x: jnp.ndarray):
    """Forward through convs then masked FCs. x: [B, C, H, W]. `masks` holds
    one entry per *masked* FC layer, in order."""
    i = 0
    h = x
    for cs in spec.convs:
        w, b = params[i], params[i + 1]
        i += 2
        h = jax.lax.conv_general_dilated(
            h, w,
            window_strides=(cs.stride, cs.stride),
            padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ) + b[None, :, None, None]
        h = jax.nn.relu(h)
        if cs.pool:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
            )
    h = h.reshape(h.shape[0], -1)
    mi = 0
    nfc = len(spec.fc_dims)
    for li in range(nfc):
        w, b = params[i], params[i + 1]
        i += 2
        if spec.masked_fc[li]:
            h = masked_linear(h, w, masks[mi]) + b
            mi += 1
        else:
            h = h @ w.T + b
        if li + 1 < nfc:
            h = jax.nn.relu(h)
    return h


def conv_train_step(spec: NetSpec, params, masks, x, y, lr):
    def loss_fn(params):
        return softmax_xent(conv_forward(spec, params, masks, x), y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = [p - lr * g for p, g in zip(params, grads)]
    # re-apply masks to updated FC weights (Algorithm 1 line 14)
    nconv = 2 * len(spec.convs)
    mi = 0
    for li in range(len(spec.fc_dims)):
        if spec.masked_fc[li]:
            wi = nconv + 2 * li
            new[wi] = new[wi] * masks[mi]
            mi += 1
    return new, loss


# --------------------------------------------------------------------------
# jit-able entrypoints (what aot.py lowers)
# --------------------------------------------------------------------------

def lenet_train_step_flat(w1, b1, w2, b2, w3, b3, m1, m2, x, y, lr):
    p, loss = lenet_train_step(LenetParams(w1, b1, w2, b2, w3, b3), m1, m2, x, y, lr)
    return (*p, loss)


def lenet_infer_flat(w1, b1, w2, b2, w3, b3, x):
    return (lenet_forward_dense(LenetParams(w1, b1, w2, b2, w3, b3), x),)


def lenet_infer_packed_flat(xp, wb1, b1p, g12, wb2, b2p, g2o, w3f, b3):
    return (lenet_infer_packed(xp, wb1, b1p, g12, wb2, b2p, g2o, w3f, b3),)


def conv_train_step_flat(spec: NetSpec, nmasks: int):
    nparams = 2 * len(spec.convs) + 2 * len(spec.fc_dims)

    def fn(*args):
        params = list(args[:nparams])
        masks = list(args[nparams:nparams + nmasks])
        x, y, lr = args[nparams + nmasks:]
        new, loss = conv_train_step(spec, params, masks, x, y, lr)
        return (*new, loss)

    return fn


def conv_infer_flat(spec: NetSpec, nmasks: int):
    nparams = 2 * len(spec.convs) + 2 * len(spec.fc_dims)

    def fn(*args):
        params = list(args[:nparams])
        masks = list(args[nparams:nparams + nmasks])
        x = args[nparams + nmasks]
        return (conv_forward(spec, params, masks, x),)

    return fn
