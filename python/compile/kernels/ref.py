"""Pure-jnp reference oracles for the Pallas kernels.

These are the *specification*: small, obviously-correct jnp expressions that
the Pallas kernels in this package are tested against (pytest + hypothesis in
``python/tests/test_kernels.py``), and that the rust-side native engine
mirrors (``rust/src/linalg/blockdiag_mm.rs``).

Tile-space convention (shared with the rust coordinator): a block-diagonal
layer with ``K`` uniform blocks of shape ``(OB, IB)`` stores weights as
``w_blocks[K, OB, IB]`` and activations as ``x_tiles[B, K*IB]`` /
``y_tiles[B, K*OB]``, where tile ``k`` of the activation occupies columns
``[k*IB, (k+1)*IB)``. Ragged layers are zero-padded to uniform tiles by the
coordinator; zero padding is exact (it contributes nothing to the GEMMs).
"""

import jax.numpy as jnp


def blockdiag_matmul_ref(x_tiles: jnp.ndarray, w_blocks: jnp.ndarray) -> jnp.ndarray:
    """y_tiles[b, k*OB + o] = sum_i x_tiles[b, k*IB + i] * w_blocks[k, o, i].

    Args:
      x_tiles: [B, K*IB] activations in tile space.
      w_blocks: [K, OB, IB] uniform packed blocks.
    Returns:
      [B, K*OB] output activations in tile space.
    """
    k, ob, ib = w_blocks.shape
    b = x_tiles.shape[0]
    xs = x_tiles.reshape(b, k, ib)
    # y[b, k, o] = sum_i xs[b, k, i] * w[k, o, i]
    y = jnp.einsum("bki,koi->bko", xs, w_blocks)
    return y.reshape(b, k * ob)


def masked_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """y = x @ (m * w).T — eq. 1 of the paper applied inside the matmul.

    Args:
      x: [B, IN] activations.
      w: [OUT, IN] weights.
      m: [OUT, IN] binary mask.
    Returns:
      [B, OUT].
    """
    return x @ (m * w).T
