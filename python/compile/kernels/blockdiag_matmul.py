"""Pallas kernel: packed block-diagonal matmul — the paper's inference
hot-spot, expressed the way a TPU wants it.

The MPDCompress insight is that after the eq.-2 inverse permutations every
masked FC layer is exactly block-diagonal: ``K`` independent dense blocks.
On the paper's GPUs each block maps to a threadblock; on TPU the natural
mapping (DESIGN.md §Hardware-Adaptation) is one Pallas *grid step* per
block, with ``BlockSpec`` expressing the HBM→VMEM schedule:

  grid = (K,)
  x tile   [B, IB]   — the slice of activations this block consumes
  w tile   [OB, IB]  — the block's weights (resident in VMEM)
  out tile [B, OB]   — written once, no cross-block accumulation

There is *no* communication between grid steps — the paper's "key enabler"
(independent sub-graphs) literally becomes the grid axis. The MXU sees a
dense ``[B, IB] @ [IB, OB]`` per step; no gathers, no index arrays
(contrast CSR-style sparse kernels).

``interpret=True`` is mandatory on this CPU-only image: real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute. The
kernel is still the real thing — the same code lowers to Mosaic on TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blockdiag_kernel(x_ref, w_ref, o_ref):
    """One grid step = one diagonal block: o = x @ w.T."""
    x = x_ref[...]            # [B, IB]  (VMEM tile)
    w = w_ref[0]              # [OB, IB] (VMEM tile; leading block axis is 1)
    # MXU-shaped contraction; on TPU this is a single systolic pass per
    # 128×128 tile. float32 accumulation.
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def blockdiag_matmul(x_tiles: jnp.ndarray, w_blocks: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Tile-space block-diagonal matmul (see kernels/ref.py for the spec).

    Args:
      x_tiles: [B, K*IB] activations in tile space (f32).
      w_blocks: [K, OB, IB] packed uniform blocks (f32).
    Returns:
      [B, K*OB] output in tile space.
    """
    k, ob, ib = w_blocks.shape
    b = x_tiles.shape[0]
    assert x_tiles.shape == (b, k * ib), (x_tiles.shape, (b, k * ib))
    return pl.pallas_call(
        _blockdiag_kernel,
        grid=(k,),
        in_specs=[
            # activations: block j reads x_tiles[:, j*IB:(j+1)*IB]
            pl.BlockSpec((b, ib), lambda j: (0, j)),
            # weights: block j reads w_blocks[j]
            pl.BlockSpec((1, ob, ib), lambda j: (j, 0, 0)),
        ],
        # output: block j writes y[:, j*OB:(j+1)*OB]
        out_specs=pl.BlockSpec((b, ob), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, k * ob), jnp.float32),
        interpret=interpret,
    )(x_tiles, w_blocks)


def vmem_bytes(batch: int, k: int, ob: int, ib: int) -> int:
    """Per-grid-step VMEM footprint estimate (f32): x tile + w block + out
    tile. Used by the DESIGN.md roofline analysis — a block must fit VMEM
    (~16 MiB on contemporary TPUs) for the schedule above to hold."""
    del k  # footprint is per-step; K only scales the grid
    return 4 * (batch * ib + ob * ib + batch * ob)


def mxu_util_estimate(batch: int, ob: int, ib: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes doing useful work for one block GEMM, given the
    128×128 systolic array: dims are padded up to multiples of `mxu`."""
    pad = lambda d: ((d + mxu - 1) // mxu) * mxu
    useful = batch * ob * ib
    padded = pad(batch) * pad(ob) * pad(ib)
    return useful / padded
