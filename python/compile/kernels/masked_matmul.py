"""Pallas kernel: fused mask-apply + matmul — the *training-mode* hot-spot.

During training (paper Fig. 2 / Algorithm 1) every FC forward computes
``y = x @ (M ∘ W).T``. Materializing ``M ∘ W`` in HBM doubles weight
traffic; this kernel fuses the element-wise mask into the matmul tiles, so
the mask load happens block-wise in VMEM right before the MXU pass.

Grid is over output tiles: step ``j`` owns rows ``[j*OT, (j+1)*OT)`` of the
weight/mask matrices and the matching output columns. The full ``x`` tile is
re-read per step (B×IN is small relative to OUT×IN at the paper's shapes).

A ``jax.custom_vjp`` wrapper (`masked_linear`) makes the kernel usable inside
the L2 training graph: forward runs the Pallas kernel, backward is the
standard masked-GEMM pair expressed in jnp (the gradient w.r.t. ``w`` is
re-masked, which also makes "apply the mask to the updated weights" a no-op
mathematically — we still re-apply post-update per Algorithm 1, belt and
braces).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_kernel(x_ref, w_ref, m_ref, o_ref):
    x = x_ref[...]                 # [B, IN]
    w = w_ref[...] * m_ref[...]    # [OT, IN] — fused mask apply in VMEM
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("out_tile", "interpret"))
def masked_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    m: jnp.ndarray,
    *,
    out_tile: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ (m * w).T with the mask fused into the weight tiles.

    Args:
      x: [B, IN] f32. w, m: [OUT, IN] f32 (m is 0/1).
      out_tile: rows of w per grid step (OUT must divide or pad handled by
        caller; we require OUT % out_tile == 0 or out_tile >= OUT).
    """
    b, inp = x.shape
    out, inp2 = w.shape
    assert inp == inp2 and w.shape == m.shape
    ot = min(out_tile, out)
    assert out % ot == 0, f"OUT={out} not divisible by tile {ot}"
    grid = (out // ot,)
    return pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, inp), lambda j: (0, 0)),
            pl.BlockSpec((ot, inp), lambda j: (j, 0)),
            pl.BlockSpec((ot, inp), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, ot), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, out), jnp.float32),
        interpret=interpret,
    )(x, w, m)


def _pick_tile(out: int) -> int:
    """Largest tile ≤128 that divides OUT (OUT=1 layers fall back to 1)."""
    for t in (128, 100, 64, 50, 32, 25, 16, 10, 8, 5, 4, 2, 1):
        if out % t == 0:
            return t
    return 1


@jax.custom_vjp
def masked_linear(x, w, m):
    """Differentiable masked FC forward running the Pallas kernel."""
    return masked_matmul(x, w, m, out_tile=_pick_tile(w.shape[0]))


def _masked_linear_fwd(x, w, m):
    return masked_linear(x, w, m), (x, w, m)


def _masked_linear_bwd(res, g):
    x, w, m = res
    wm = w * m
    dx = g @ wm                       # [B, IN]
    dw = (g.T @ x) * m                # masked gradient — off-mask stays 0
    return dx, dw, jnp.zeros_like(m)


masked_linear.defvjp(_masked_linear_fwd, _masked_linear_bwd)
