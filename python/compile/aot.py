"""AOT lowering driver: JAX entrypoints → HLO *text* artifacts + metadata.

Run once at build time (``make artifacts``); the rust coordinator loads the
HLO text via ``HloModuleProto::from_text_file`` and never touches Python.

HLO text — NOT ``lowered.compile()`` output or a serialized HloModuleProto —
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (what the published ``xla`` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Each artifact ``<name>.hlo.txt`` gets a sidecar ``<name>.meta.json``::

    {"name": ..., "inputs": [{"shape": [...], "dtype": "f32"}, ...],
     "outputs": [...]}

and ``manifest.txt`` lists all artifact names (one per line) — the rust
``runtime::Manifest`` parses both.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(d).name]


def lower_entry(name: str, fn, arg_specs, out_dir: str) -> dict:
    """Lower `fn(*arg_specs)`, write artifact + meta, return meta dict."""
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    # output shapes from the lowered signature
    out_avals = lowered.out_info
    outs = jax.tree_util.tree_leaves(out_avals)
    meta = {
        "name": name,
        "inputs": [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)} for s in arg_specs],
        "outputs": [{"shape": list(o.shape), "dtype": _dtype_name(o.dtype)} for o in outs],
    }
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  {name}: {len(text)} chars, {len(meta['inputs'])} in / {len(meta['outputs'])} out")
    return meta


# ---------------------------------------------------------------------------
# entrypoint catalogue
# ---------------------------------------------------------------------------

def lenet_entries(out_dir):
    d = model.LENET_DIMS
    pshapes = [(d[1], d[0]), (d[1],), (d[2], d[1]), (d[2],), (d[3], d[2]), (d[3],)]
    mshapes = [(d[1], d[0]), (d[2], d[1])]
    metas = []
    for batch in (50,):
        args = (
            [_spec(s) for s in pshapes]
            + [_spec(s) for s in mshapes]
            + [_spec((batch, d[0])), _spec((batch,), jnp.int32), _spec(())]
        )
        metas.append(lower_entry(f"lenet_train_step_b{batch}", model.lenet_train_step_flat, args, out_dir))
    for batch in (1, 32, 256):
        args = [_spec(s) for s in pshapes] + [_spec((batch, d[0]))]
        metas.append(lower_entry(f"lenet_infer_b{batch}", model.lenet_infer_flat, args, out_dir))
    # packed inference at k=10 (paper's 10% sparsity): tile dims
    k = 10
    ib1, ob1 = -(-d[0] // k), -(-d[1] // k)   # 79, 30
    ib2, ob2 = -(-d[1] // k), -(-d[2] // k)   # 30, 10
    for batch in (1, 32, 256):
        args = [
            _spec((batch, k * ib1)),            # xp
            _spec((k, ob1, ib1)),               # wb1
            _spec((k * ob1,)),                  # b1p
            _spec((k * ib2,), jnp.int32),       # g12
            _spec((k, ob2, ib2)),               # wb2
            _spec((k * ob2,)),                  # b2p
            _spec((d[2],), jnp.int32),          # g2o
            _spec((d[3], d[2])),                # w3f
            _spec((d[3],)),                     # b3
        ]
        metas.append(lower_entry(f"lenet_infer_packed_k10_b{batch}", model.lenet_infer_packed_flat, args, out_dir))
    return metas


def conv_entries(spec: model.NetSpec, out_dir, train_batch=32, infer_batch=128):
    nmask = sum(spec.masked_fc)
    pshapes = []
    in_c = spec.in_shape[0]
    for cs in spec.convs:
        pshapes.append((cs.out_c, in_c, cs.kernel, cs.kernel))
        pshapes.append((cs.out_c,))
        in_c = cs.out_c
    fc_shapes = spec.fc_shapes()
    for s in fc_shapes:
        pshapes.append(s)
        pshapes.append((s[0],))
    mshapes = [s for s, masked in zip(fc_shapes, spec.masked_fc) if masked]
    c, h, w = spec.in_shape
    metas = []
    args = (
        [_spec(s) for s in pshapes]
        + [_spec(s) for s in mshapes]
        + [_spec((train_batch, c, h, w)), _spec((train_batch,), jnp.int32), _spec(())]
    )
    metas.append(lower_entry(
        f"{spec.name}_train_step_b{train_batch}",
        model.conv_train_step_flat(spec, nmask), args, out_dir))
    args = [_spec(s) for s in pshapes] + [_spec(s) for s in mshapes] + [_spec((infer_batch, c, h, w))]
    metas.append(lower_entry(
        f"{spec.name}_infer_b{infer_batch}",
        model.conv_infer_flat(spec, nmask), args, out_dir))
    return metas


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="comma-separated model filter (lenet,deep_mnist,cifar10,tiny_alexnet)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    print(f"lowering artifacts into {out_dir} (jax {jax.__version__})")
    metas = []
    if only is None or "lenet" in only:
        metas += lenet_entries(out_dir)
    for name, spec in model.SPECS.items():
        if only is None or name in only:
            metas += conv_entries(spec, out_dir)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for m in metas:
            f.write(m["name"] + "\n")
    print(f"wrote {len(metas)} artifacts + manifest")


if __name__ == "__main__":
    main()
